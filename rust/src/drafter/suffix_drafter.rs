//! The DAS adaptive nonparametric drafter (§4.1.2).
//!
//! History scoping (Fig. 6):
//! * `Problem` — one history shard per problem (the paper's default:
//!   per-problem patterns transfer poorly across problems, and small
//!   shards are cheap to query).
//! * `ProblemRequest` — per-problem shard PLUS a request-local index over
//!   the tokens generated so far in the current request (captures
//!   self-repetition; higher acceptance, more query cost).
//! * `GlobalRequest` — one big global shard plus the request-local index
//!   (the strawman that is slower due to the single large tree).
//!
//! An optional prefix-trie router (§4.1.2 "per-request suffix trees")
//! routes the decode prefix to the most similar prior generation's shard
//! before querying.
//!
//! This drafter is the routing layer only: every shard (and the
//! request-local index) is a `Box<dyn DraftSource>` — the substrate behind
//! speculation is chosen by `spec.substrate` ("window" = the fused
//! epoch-tagged arena trie, "tree" = Ukkonen, "array" = rebuild-per-insert
//! suffix array) and nothing here names a concrete structure. Scope rules,
//! minimum-match thresholds and router fallbacks apply identically to all
//! substrates.
//!
//! Trie-backed shards, request-local indexes AND the prefix router share
//! one [`SharedPool`]: identical interned content (the same rollout hitting
//! several shards, a re-sampled problem, a repeated router prefix) is
//! stored once, and every index's label bytes are visible through the one
//! pool the drafter reports in its gauges. (The hash-cons dedups whole
//! token runs — the router's depth-capped prefixes and per-round
//! request-local fragments mostly intern their own short segments.)

use std::collections::HashMap;
use std::sync::Arc;

use super::{
    source_from_substrate_pooled, Draft, DraftOutcome, DraftSnapshot, DraftSource, Drafter,
    DrafterSnapshot, IndexStats,
};
use crate::config::SpecConfig;
use crate::draftsvc::{Fingerprint, RemoteDraftSource, RemoteDraftStats, RemoteSession, ShardKey};
use crate::store::wire::{Reader, StoreError, Writer};
use crate::suffix::{PrefixRouter, RouterSnapshot, SharedPool, SuffixTrieIndex};
use crate::tokens::{Epoch, ProblemId, RequestId, Rollout, TokenId};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistoryScope {
    Problem,
    ProblemRequest,
    GlobalRequest,
}

impl HistoryScope {
    pub fn parse(s: &str) -> Option<HistoryScope> {
        match s {
            "problem" => Some(HistoryScope::Problem),
            "problem+request" => Some(HistoryScope::ProblemRequest),
            "global+request" => Some(HistoryScope::GlobalRequest),
            _ => None,
        }
    }

    pub fn uses_request_local(self) -> bool {
        matches!(self, HistoryScope::ProblemRequest | HistoryScope::GlobalRequest)
    }

    /// The config-string spelling (inverse of [`HistoryScope::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            HistoryScope::Problem => "problem",
            HistoryScope::ProblemRequest => "problem+request",
            HistoryScope::GlobalRequest => "global+request",
        }
    }
}

pub struct SuffixDrafter {
    scope: HistoryScope,
    /// Substrate selector for history shards (`spec.substrate`).
    substrate: String,
    /// Per-problem history shards (Problem / ProblemRequest scopes).
    shards: HashMap<ProblemId, Box<dyn DraftSource>>,
    /// Single global shard (GlobalRequest scope).
    global: Box<dyn DraftSource>,
    /// Request-local indexes over the tokens generated so far (always a
    /// counting trie: self-repetition wants frequency weighting and dies
    /// with the request, so windowing is moot).
    request_local: HashMap<RequestId, Box<dyn DraftSource>>,
    /// Optional prefix router over prior generations of each problem.
    router: Option<PrefixRouter>,
    /// Label-segment pool shared by every trie-backed shard + the router.
    pool: SharedPool,
    /// `substrate = "remote"` only: the shared client session every shard
    /// draws on. History shards become server-side views; request-local
    /// indexes and the router stay client-side (they are per-process by
    /// nature and die with their requests).
    remote: Option<Arc<RemoteSession>>,
    window: usize,
    match_len: usize,
    /// Minimum context-suffix match depth before a history draft is trusted.
    min_match: usize,
    max_depth: usize,
    epoch: Epoch,
    /// Drafts answered from the request-local index (diagnostics).
    pub local_hits: u64,
    pub shard_hits: u64,
    pub misses: u64,
    /// Cached drafter-level snapshot, invalidated by every history
    /// mutation (absorb / partial / end-request / epoch roll / route
    /// registration / warm start) — repeat publishes between mutations are
    /// `Arc` clones.
    snap: Option<Arc<DrafterSnapshot>>,
}

impl SuffixDrafter {
    pub fn new(
        scope: HistoryScope,
        window: usize,
        match_len: usize,
        budget_cap: usize,
        use_router: bool,
    ) -> Self {
        Self::with_substrate(scope, "window", window, match_len, budget_cap, use_router)
    }

    pub fn with_substrate(
        scope: HistoryScope,
        substrate: &str,
        window: usize,
        match_len: usize,
        budget_cap: usize,
        use_router: bool,
    ) -> Self {
        Self::configured(scope, substrate, window, match_len, budget_cap, use_router, 0)
    }

    /// Full constructor: `router_capacity` bounds the registrations the
    /// prefix router keeps per shard (FIFO eviction); 0 = unbounded (the
    /// historical behavior). Wired from `spec.router_capacity`.
    pub fn configured(
        scope: HistoryScope,
        substrate: &str,
        window: usize,
        match_len: usize,
        budget_cap: usize,
        use_router: bool,
        router_capacity: usize,
    ) -> Self {
        let max_depth = match_len + budget_cap.max(8);
        let pool = SharedPool::new();
        SuffixDrafter {
            scope,
            substrate: substrate.to_string(),
            shards: HashMap::new(),
            global: source_from_substrate_pooled(substrate, window, max_depth, Some(&pool)),
            request_local: HashMap::new(),
            router: if use_router {
                let cap = if router_capacity == 0 {
                    usize::MAX
                } else {
                    router_capacity
                };
                Some(PrefixRouter::with_capacity_pooled(
                    match_len.max(8),
                    cap,
                    pool.clone(),
                ))
            } else {
                None
            },
            pool,
            remote: None,
            window,
            match_len,
            min_match: 2.min(match_len),
            max_depth,
            epoch: 0,
            local_hits: 0,
            shard_hits: 0,
            misses: 0,
            snap: None,
        }
    }

    pub fn from_config(cfg: &SpecConfig) -> Self {
        if cfg.substrate == "remote" {
            return SuffixDrafter::remote_from_config(cfg);
        }
        // audit: allow(panic-path) -- config validate() already parsed this scope; see validate()
        let scope = HistoryScope::parse(&cfg.scope).expect("validated scope");
        SuffixDrafter::configured(
            scope,
            &cfg.substrate,
            cfg.window,
            cfg.match_len,
            cfg.budget_cap,
            cfg.prefix_router,
            cfg.router_capacity,
        )
    }

    /// The `substrate = "remote"` drafter: identical routing layer, but
    /// history shards are [`RemoteDraftSource`] views onto one
    /// `das serve-drafts` daemon at `spec.draft_addr`. The handshake
    /// fingerprint pins the shard geometry, so the server's local shards
    /// answer exactly what in-process shards would.
    fn remote_from_config(cfg: &SpecConfig) -> Self {
        // audit: allow(panic-path) -- config validate() already parsed this scope; see validate()
        let scope = HistoryScope::parse(&cfg.scope).expect("validated scope");
        let max_depth = cfg.match_len + cfg.budget_cap.max(8);
        let session = Arc::new(RemoteSession::new(
            &cfg.draft_addr,
            cfg.draft_timeout_ms,
            cfg.draft_retries,
            Fingerprint {
                window: cfg.window,
                match_len: cfg.match_len,
                max_depth,
                scope: scope.as_str().to_string(),
            },
        ));
        let pool = SharedPool::new();
        SuffixDrafter {
            scope,
            substrate: "remote".to_string(),
            shards: HashMap::new(),
            global: Box::new(RemoteDraftSource::new(Arc::clone(&session), ShardKey::Global)),
            request_local: HashMap::new(),
            router: if cfg.prefix_router {
                let cap = if cfg.router_capacity == 0 {
                    usize::MAX
                } else {
                    cfg.router_capacity
                };
                Some(PrefixRouter::with_capacity_pooled(
                    cfg.match_len.max(8),
                    cap,
                    pool.clone(),
                ))
            } else {
                None
            },
            pool,
            remote: Some(session),
            window: cfg.window,
            match_len: cfg.match_len,
            min_match: 2.min(cfg.match_len),
            max_depth,
            epoch: 0,
            local_hits: 0,
            shard_hits: 0,
            misses: 0,
            snap: None,
        }
    }

    pub fn scope(&self) -> HistoryScope {
        self.scope
    }

    /// Name of the substrate backing history shards.
    pub fn substrate(&self) -> &str {
        &self.substrate
    }

    /// Sliding-window size in epochs (0 = unbounded).
    pub fn window(&self) -> usize {
        self.window
    }

    /// Last epoch this drafter was rolled to (restored by warm starts).
    pub fn epoch(&self) -> Epoch {
        self.epoch
    }

    /// Maximum context-suffix match depth per draft (`spec.match_len`).
    pub fn match_len(&self) -> usize {
        self.match_len
    }

    /// Index depth cap (`match_len + budget_cap.max(8)`).
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    fn new_shard(&self, problem: ProblemId) -> Box<dyn DraftSource> {
        match &self.remote {
            Some(session) => Box::new(RemoteDraftSource::new(
                Arc::clone(session),
                ShardKey::Problem(problem),
            )),
            None => source_from_substrate_pooled(
                &self.substrate,
                self.window,
                self.max_depth,
                Some(&self.pool),
            ),
        }
    }

    /// Total tokens currently indexed across history shards (diagnostics;
    /// Fig. 6-right's "bigger index = slower" effect is real work here).
    pub fn indexed_tokens(&self) -> usize {
        match self.scope {
            HistoryScope::GlobalRequest => self.global.indexed_tokens(),
            _ => self.shards.values().map(|w| w.indexed_tokens()).sum(),
        }
    }

    /// Rebuild a drafter purely from a `das-store-v1` snapshot payload —
    /// every parameter the payload needs (scope, substrate, window, depth
    /// cap, router shape) is stored inside it, so offline tools (`das store
    /// inspect|verify|compact`) need no config file. Request-local indexes
    /// are NOT part of a snapshot: they die with their requests, and
    /// request ids do not survive a restart. The shared pool reconciles
    /// after load — segments only those ephemeral indexes referenced are
    /// dropped, and the second return value counts recorded-vs-rederived
    /// refcount disagreements (0 for a quiescent snapshot).
    pub fn from_state_verified(bytes: &[u8]) -> Result<(SuffixDrafter, usize), StoreError> {
        let mut r = Reader::new(bytes);
        r.expect_str("das-suffix", "drafter snapshot tag")?;
        let ver = r.u8()?;
        if ver != 1 {
            return Err(StoreError::Version(format!(
                "das-suffix payload version {ver} (this build speaks 1)"
            )));
        }
        let scope_s = r.str()?;
        let scope = HistoryScope::parse(&scope_s)
            .ok_or_else(|| StoreError::Corrupt(format!("unknown scope '{scope_s}'")))?;
        let substrate = r.str()?;
        if !matches!(substrate.as_str(), "window" | "tree" | "array") {
            return Err(StoreError::Corrupt(format!("unknown substrate '{substrate}'")));
        }
        let window = r.usize()?;
        let match_len = r.usize()?;
        let max_depth = r.usize()?;
        let epoch = r.u32()?;
        let local_hits = r.u64()?;
        let shard_hits = r.u64()?;
        let misses = r.u64()?;
        let (pool, recorded) = SharedPool::load_state(&mut r)?;
        let mut global = source_from_substrate_pooled(&substrate, window, max_depth, Some(&pool));
        global.load_state(&mut r)?;
        let n_shards = r.count(4)?;
        let mut shards: HashMap<ProblemId, Box<dyn DraftSource>> =
            HashMap::with_capacity(n_shards);
        for _ in 0..n_shards {
            let problem = r.u32()?;
            let mut shard =
                source_from_substrate_pooled(&substrate, window, max_depth, Some(&pool));
            shard.load_state(&mut r)?;
            if shards.insert(problem, shard).is_some() {
                return Err(StoreError::Corrupt(format!("shard {problem} duplicated")));
            }
        }
        let router = match r.u8()? {
            0 => None,
            1 => Some(PrefixRouter::load_state(&mut r, pool.clone())?),
            t => return Err(StoreError::Corrupt(format!("bad router flag {t}"))),
        };
        if !r.is_empty() {
            return Err(StoreError::Corrupt("trailing bytes in drafter snapshot".into()));
        }
        let mismatches = pool.reconcile_recorded(&recorded);
        Ok((
            SuffixDrafter {
                scope,
                substrate,
                shards,
                global,
                request_local: HashMap::new(),
                router,
                pool,
                remote: None,
                window,
                match_len,
                min_match: 2.min(match_len),
                max_depth,
                epoch,
                local_hits,
                shard_hits,
                misses,
                snap: None,
            },
            mismatches,
        ))
    }

    /// [`SuffixDrafter::from_state_verified`] without the refcount report.
    pub fn from_state(bytes: &[u8]) -> Result<SuffixDrafter, StoreError> {
        Self::from_state_verified(bytes).map(|(d, _)| d)
    }

    fn history_draft(&self, problem: ProblemId, context: &[TokenId], budget: usize) -> Draft {
        let source: Option<&dyn DraftSource> = match self.scope {
            HistoryScope::GlobalRequest => Some(&*self.global),
            _ => self.shards.get(&problem).map(|s| &**s),
        };
        let Some(source) = source else { return Draft::empty() };
        let d = source.draft_from(context, self.match_len, budget);
        // Require a minimum match depth: a 1-token suffix match is usually
        // a coincidental token collision somewhere in history, and drafting
        // from it wastes verification budget (the same reason
        // SuffixDecoding thresholds its pattern-match scores).
        if !d.is_empty() && d.match_len >= self.min_match {
            d
        } else {
            Draft::empty()
        }
    }
}

/// The adaptive drafter's routing state, frozen at a publish point: every
/// shard's (and request-local index's) [`DraftSnapshot`] plus the router
/// snapshot, with the same scope rules and minimum-match thresholds as the
/// serial path. Built by [`Drafter::snapshot`] on [`SuffixDrafter`];
/// drafting takes `&self` and acquires no lock.
#[derive(Debug, Clone)]
pub(super) struct SuffixDrafterSnapshot {
    scope: HistoryScope,
    match_len: usize,
    min_match: usize,
    /// Per-problem shard snapshots (Problem / ProblemRequest scopes).
    shards: HashMap<ProblemId, DraftSnapshot>,
    /// Global shard snapshot (GlobalRequest scope).
    global: Option<DraftSnapshot>,
    /// Request-local index snapshots ("+request" scopes).
    request_local: HashMap<RequestId, DraftSnapshot>,
    router: Option<Arc<RouterSnapshot>>,
}

impl SuffixDrafterSnapshot {
    /// Mirrors [`SuffixDrafter::history_draft`] over the published shards.
    fn history_draft(&self, problem: ProblemId, context: &[TokenId], budget: usize) -> Draft {
        let source = match self.scope {
            HistoryScope::GlobalRequest => self.global.as_ref(),
            _ => self.shards.get(&problem),
        };
        let Some(source) = source else { return Draft::empty() };
        let d = source.draft_from(context, self.match_len, budget);
        if !d.is_empty() && d.match_len >= self.min_match {
            d
        } else {
            Draft::empty()
        }
    }

    /// Raw shard read for the draft service: one shard (`None` = global),
    /// no routing, no minimum-match gating — the CLIENT drafter applies
    /// its own thresholds, which is what keeps remote drafts bit-identical
    /// to in-process ones.
    pub(super) fn shard_draft(
        &self,
        shard: Option<ProblemId>,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> Draft {
        let source = match shard {
            None => self.global.as_ref(),
            Some(problem) => self.shards.get(&problem),
        };
        match source {
            Some(s) => s.draft_from(context, max_match, budget),
            None => Draft::empty(),
        }
    }

    /// Mirrors the serial `Drafter::draft` routing exactly (request-local
    /// first, then router redirect, then own-problem fallback), reporting
    /// the outcome instead of bumping counters.
    pub(super) fn draft(
        &self,
        request: RequestId,
        problem: ProblemId,
        context: &[TokenId],
        budget: usize,
    ) -> (Draft, DraftOutcome) {
        if self.scope.uses_request_local() {
            if let Some(local) = self.request_local.get(&request) {
                let d = local.draft_from(context, self.match_len, budget);
                if !d.is_empty() && d.match_len >= 3.min(self.match_len) {
                    return (d, DraftOutcome::Local);
                }
            }
        }
        let routed_problem = match &self.router {
            Some(r) => r.route(context).map(|(shard, _)| shard).unwrap_or(problem),
            None => problem,
        };
        let d = self.history_draft(routed_problem, context, budget);
        if d.is_empty() && routed_problem != problem {
            let d2 = self.history_draft(problem, context, budget);
            let outcome = if d2.is_empty() {
                DraftOutcome::Miss
            } else {
                DraftOutcome::Shard
            };
            return (d2, outcome);
        }
        let outcome = if d.is_empty() {
            DraftOutcome::Miss
        } else {
            DraftOutcome::Shard
        };
        (d, outcome)
    }
}

impl Drafter for SuffixDrafter {
    fn name(&self) -> &'static str {
        "das-suffix"
    }

    fn draft(
        &mut self,
        request: RequestId,
        problem: ProblemId,
        context: &[TokenId],
        budget: usize,
    ) -> Draft {
        if budget == 0 || context.is_empty() {
            return Draft::empty();
        }
        // Request-local first: self-repetition within a generation is the
        // strongest signal when present (loops, repeated derivation steps).
        if self.scope.uses_request_local() {
            if let Some(local) = self.request_local.get(&request) {
                let d = local.draft_from(context, self.match_len, budget);
                // Only trust local matches that are reasonably deep.
                if !d.is_empty() && d.match_len >= 3.min(self.match_len) {
                    self.local_hits += 1;
                    return d;
                }
            }
        }
        // Router: narrow the context to the shard of the most similar prior
        // generation. (Per-problem shards already give strong locality; the
        // router mainly matters for the global scope, mirroring §4.1.2's
        // note that its benefit is workload-dependent.)
        let routed_problem = match &self.router {
            Some(r) => r.route(context).map(|(shard, _)| shard).unwrap_or(problem),
            None => problem,
        };
        let d = self.history_draft(routed_problem, context, budget);
        if d.is_empty() && routed_problem != problem {
            // Router miss: fall back to the request's own problem shard.
            let d2 = self.history_draft(problem, context, budget);
            if d2.is_empty() {
                self.misses += 1;
            } else {
                self.shard_hits += 1;
            }
            return d2;
        }
        if d.is_empty() {
            self.misses += 1;
        } else {
            self.shard_hits += 1;
        }
        d
    }

    /// Publish (or reuse) the drafter-level snapshot: every shard and
    /// request-local index publishes its substrate snapshot (each cached at
    /// that level too), the router publishes its trie view, and the whole
    /// bundle is frozen behind one `Arc` for the draft worker threads.
    fn snapshot(&mut self) -> Option<Arc<DrafterSnapshot>> {
        if let Some(s) = &self.snap {
            return Some(Arc::clone(s));
        }
        let mut shards = HashMap::with_capacity(self.shards.len());
        let mut global = None;
        match self.scope {
            HistoryScope::GlobalRequest => global = Some(self.global.snapshot()),
            _ => {
                for (problem, shard) in self.shards.iter_mut() {
                    shards.insert(*problem, shard.snapshot());
                }
            }
        }
        let request_local = self
            .request_local
            .iter_mut()
            .map(|(request, local)| (*request, local.snapshot()))
            .collect();
        let router = self.router.as_mut().map(|r| r.publish());
        let s = Arc::new(DrafterSnapshot::suffix(
            self.epoch,
            SuffixDrafterSnapshot {
                scope: self.scope,
                match_len: self.match_len,
                min_match: self.min_match,
                shards,
                global,
                request_local,
                router,
            },
        ));
        self.snap = Some(Arc::clone(&s));
        Some(s)
    }

    fn apply_draft_outcomes(&mut self, local_hits: u64, shard_hits: u64, misses: u64) {
        self.local_hits += local_hits;
        self.shard_hits += shard_hits;
        self.misses += misses;
    }

    fn observe_partial(&mut self, request: RequestId, _problem: ProblemId, new_tokens: &[TokenId]) {
        if !self.scope.uses_request_local() || new_tokens.is_empty() {
            return;
        }
        self.snap = None;
        // Request-local index: re-index the request's committed tokens.
        // Cheap because requests are bounded and the trie depth is capped.
        // It shares the drafter pool so its label bytes show up in the
        // telemetry gauges and die as dead-segment bytes (reclaimed by the
        // pool's >50%-dead rewrite) when the request ends.
        let max_depth = self.max_depth;
        let epoch = self.epoch;
        let pool = self.pool.clone();
        let entry = self.request_local.entry(request).or_insert_with(|| {
            Box::new(SuffixTrieIndex::with_pool(max_depth, pool)) as Box<dyn DraftSource>
        });
        entry.absorb(epoch, new_tokens);
    }

    fn end_request(&mut self, request: RequestId) {
        if self.request_local.remove(&request).is_some() {
            self.snap = None;
        }
    }

    fn observe_rollout(&mut self, rollout: &Rollout) {
        if rollout.tokens.is_empty() {
            return;
        }
        self.snap = None;
        match self.scope {
            HistoryScope::GlobalRequest => self.global.absorb(rollout.epoch, &rollout.tokens),
            _ => {
                if !self.shards.contains_key(&rollout.problem) {
                    let shard = self.new_shard(rollout.problem);
                    self.shards.insert(rollout.problem, shard);
                }
                if let Some(shard) = self.shards.get_mut(&rollout.problem) {
                    shard.absorb(rollout.epoch, &rollout.tokens);
                }
            }
        }
        if let Some(router) = &mut self.router {
            router.register(rollout.problem, &rollout.tokens);
        }
    }

    fn roll_epoch(&mut self, epoch: Epoch) {
        self.snap = None;
        self.epoch = epoch;
        self.global.on_epoch(epoch);
        for shard in self.shards.values_mut() {
            shard.on_epoch(epoch);
        }
    }

    fn persistent(&self) -> bool {
        // Remote shards are views: the SERVER owns the history and its
        // durability (store dir, WAL, snapshot commits). A client-side
        // store would persist nothing but empty stubs.
        self.remote.is_none()
    }

    /// The `das-store-v1` drafter payload: parameters, the shared segment
    /// pool (ONCE — every shard's `SegRef`s point into it), the global
    /// shard, every per-problem shard (ascending problem id, so identical
    /// states serialize to identical bytes), and the router. Request-local
    /// indexes are ephemeral and excluded (see
    /// [`SuffixDrafter::from_state_verified`]).
    fn save_state(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.str("das-suffix");
        w.u8(1);
        w.str(self.scope.as_str());
        w.str(&self.substrate);
        w.usize(self.window);
        w.usize(self.match_len);
        w.usize(self.max_depth);
        w.u32(self.epoch);
        w.u64(self.local_hits);
        w.u64(self.shard_hits);
        w.u64(self.misses);
        self.pool.save_state(&mut w);
        self.global.save_state(&mut w);
        w.usize(self.shards.len());
        let mut problems: Vec<&ProblemId> = self.shards.keys().collect();
        problems.sort_unstable();
        for &p in problems {
            w.u32(p);
            self.shards[&p].save_state(&mut w);
        }
        match &self.router {
            Some(router) => {
                w.u8(1);
                router.save_state(&mut w);
            }
            None => w.u8(0),
        }
        w.into_bytes()
    }

    /// Warm start: restore from a snapshot payload, REFUSING parameter
    /// drift — a snapshot taken under a different scope/substrate/window/
    /// match-depth/router shape answers [`StoreError::Mismatch`] and leaves
    /// this drafter untouched (the engine then falls back to a cold start
    /// rather than speculating from a history indexed under other rules).
    fn load_state(&mut self, bytes: &[u8]) -> Result<(), StoreError> {
        let loaded = SuffixDrafter::from_state(bytes)?;
        let mismatch = |what: &str, got: &str, want: &str| {
            Err(StoreError::Mismatch(format!(
                "snapshot {what} '{got}' != configured '{want}'"
            )))
        };
        if loaded.scope != self.scope {
            return mismatch("scope", loaded.scope.as_str(), self.scope.as_str());
        }
        if loaded.substrate != self.substrate {
            return mismatch("substrate", &loaded.substrate, &self.substrate);
        }
        if loaded.window != self.window {
            return mismatch("window", &loaded.window.to_string(), &self.window.to_string());
        }
        if loaded.match_len != self.match_len || loaded.max_depth != self.max_depth {
            return Err(StoreError::Mismatch(format!(
                "snapshot match/depth {}x{} != configured {}x{}",
                loaded.match_len, loaded.max_depth, self.match_len, self.max_depth
            )));
        }
        match (&loaded.router, &self.router) {
            (None, None) => {}
            (Some(a), Some(b)) if a.capacity() == b.capacity() => {}
            _ => {
                return Err(StoreError::Mismatch(
                    "snapshot router configuration differs".into(),
                ));
            }
        }
        *self = loaded;
        Ok(())
    }

    fn register_route(&mut self, shard: u32, tokens: &[TokenId]) {
        if let Some(router) = &mut self.router {
            self.snap = None;
            router.register(shard, tokens);
        }
        // Mirror the registration server-side so the daemon's persisted
        // router state matches what this client routes on.
        if let Some(session) = &self.remote {
            session.register(shard, tokens);
        }
    }

    fn remote_stats(&mut self) -> Option<RemoteDraftStats> {
        self.remote.as_ref().map(|s| s.drain_stats())
    }

    fn kill_remote(&mut self) {
        if let Some(session) = &self.remote {
            session.send_die();
        }
    }

    /// Sum of every source's structure gauges, plus the shared segment
    /// pool reported ONCE (per-source stats leave pool fields 0 so a pool
    /// backing N shards isn't counted N times).
    fn index_stats(&self) -> IndexStats {
        let mut s = IndexStats::default();
        match self.scope {
            HistoryScope::GlobalRequest => s.add(&self.global.index_stats()),
            _ => {
                for shard in self.shards.values() {
                    s.add(&shard.index_stats());
                }
            }
        }
        for local in self.request_local.values() {
            s.add(&local.index_stats());
        }
        let ps = self.pool.stats();
        s.pool_segments = ps.segments;
        s.pool_tokens = ps.live_tokens;
        s.pool_bytes = ps.heap_bytes;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rollout(problem: ProblemId, epoch: Epoch, tokens: Vec<TokenId>) -> Rollout {
        Rollout {
            problem,
            epoch,
            step: 0,
            tokens,
            reward: 0.0,
        }
    }

    #[test]
    fn per_problem_isolation() {
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 8, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3, 4, 5]));
        d.observe_rollout(&rollout(2, 0, vec![1, 2, 9, 9, 9]));
        // Problem 1 context retrieves problem-1 continuations only.
        let draft = d.draft(100, 1, &[1, 2], 3);
        assert_eq!(draft.tokens, vec![3, 4, 5]);
        // Problem 2 shard differs.
        let draft = d.draft(101, 2, &[1, 2], 3);
        assert_eq!(draft.tokens, vec![9, 9, 9]);
        // Unknown problem: nothing.
        assert!(d.draft(102, 3, &[1, 2], 3).is_empty());
    }

    #[test]
    fn global_scope_shares_across_problems() {
        let mut d = SuffixDrafter::new(HistoryScope::GlobalRequest, 8, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3, 4]));
        let draft = d.draft(100, 999, &[1, 2], 2);
        assert_eq!(draft.tokens, vec![3, 4]);
    }

    #[test]
    fn request_local_self_repetition() {
        let mut d = SuffixDrafter::new(HistoryScope::ProblemRequest, 8, 8, 16, false);
        // No history at all, but the request repeats itself.
        d.observe_partial(7, 1, &[10, 11, 12, 13, 10, 11, 12]);
        let draft = d.draft(7, 1, &[10, 11, 12], 1);
        assert_eq!(draft.tokens, vec![13]);
        assert_eq!(d.local_hits, 1);
        // After the request ends, local state is dropped.
        d.end_request(7);
        assert!(d.draft(7, 1, &[10, 11, 12], 1).is_empty());
    }

    #[test]
    fn window_eviction_forgets_old_epochs() {
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 2, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3]));
        for e in 1..4 {
            d.roll_epoch(e);
            d.observe_rollout(&rollout(1, e, vec![7, 8, 9]));
        }
        assert!(d.draft(1, 1, &[1, 2], 2).is_empty(), "epoch-0 must be evicted");
        assert_eq!(d.draft(2, 1, &[7, 8], 2).tokens, vec![9]);
    }

    #[test]
    fn router_routes_to_similar_generation() {
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 8, 8, 16, true);
        d.observe_rollout(&rollout(1, 0, vec![5, 6, 7, 8]));
        // Context starts exactly like problem 1's prior generation; even if
        // the engine thinks it's problem 42 (e.g. shared prefix patterns),
        // the router redirects to shard 1.
        let draft = d.draft(9, 42, &[5, 6, 7], 1);
        assert_eq!(draft.tokens, vec![8]);
    }

    #[test]
    fn zero_budget_or_empty_context() {
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 8, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3]));
        assert!(d.draft(1, 1, &[1, 2], 0).is_empty());
        assert!(d.draft(1, 1, &[], 4).is_empty());
    }

    #[test]
    fn acceptance_improves_with_fresh_history() {
        // Sanity for the Fig. 4 mechanism: once recent rollouts are indexed,
        // drafts match the current policy's continuations.
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 4, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3, 4, 5, 6]));
        // Policy drifted: new rollouts continue differently.
        d.roll_epoch(1);
        d.observe_rollout(&rollout(1, 1, vec![1, 2, 30, 40, 50, 60]));
        d.roll_epoch(2);
        d.observe_rollout(&rollout(1, 2, vec![1, 2, 30, 40, 50, 60]));
        let draft = d.draft(5, 1, &[1, 2], 4);
        // Recent continuation (30,40,...) outvotes the stale one (3,4,...).
        assert_eq!(draft.tokens[0], 30);
    }

    #[test]
    fn shards_share_one_interned_pool() {
        // Two problems see the SAME rollout content: the second shard's
        // paths are new trie nodes, but the label bytes hash-cons to the
        // segment the first shard interned — the cross-shard dedup the
        // shared pool exists for.
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 8, 8, 16, false);
        let tokens: Vec<u32> = (0..64).map(|i| i % 13).collect();
        d.observe_rollout(&rollout(1, 0, tokens.clone()));
        let after_one = d.index_stats();
        assert!(after_one.pool_tokens > 0);
        d.observe_rollout(&rollout(2, 0, tokens.clone()));
        let after_two = d.index_stats();
        assert_eq!(
            after_two.pool_tokens, after_one.pool_tokens,
            "identical content across shards adds zero pool bytes"
        );
        assert!(after_two.nodes > after_one.nodes, "but each shard has its own paths");
        // Compression gauge: nodes never exceed uncompressed positions.
        assert!(after_two.nodes <= after_two.token_positions);
        // Both shards draft independently.
        assert_eq!(d.draft(100, 1, &[0, 1], 2).tokens, d.draft(101, 2, &[0, 1], 2).tokens);
    }

    #[test]
    fn router_capacity_bounds_registrations() {
        // configured() wires spec.router_capacity into the router's FIFO
        // eviction: with capacity 1 per shard, only the newest generation
        // of a problem stays routable.
        let mut d = SuffixDrafter::configured(HistoryScope::Problem, "window", 8, 8, 16, true, 1);
        d.observe_rollout(&rollout(1, 0, vec![5, 6, 7, 8]));
        d.observe_rollout(&rollout(1, 0, vec![20, 21, 22, 23]));
        // The old generation's route is evicted; its shard content remains
        // (capacity bounds the ROUTER, not history), so the draft for the
        // old prefix falls back to the problem shard and still succeeds
        // when the engine names the right problem.
        assert_eq!(d.draft(9, 1, &[5, 6, 7], 1).tokens, vec![8]);
        // A foreign problem id only reaches shard 1 via the router, which
        // now only knows the newest generation.
        assert_eq!(d.draft(10, 42, &[20, 21, 22], 1).tokens, vec![23]);
        assert!(d.draft(11, 42, &[5, 6, 7], 1).is_empty(), "evicted route");
    }

    /// Round-trip helper: save → from_state, asserting zero refcount drift.
    fn roundtrip(d: &SuffixDrafter) -> SuffixDrafter {
        let bytes = d.save_state();
        let (restored, rc_mismatches) =
            SuffixDrafter::from_state_verified(&bytes).expect("snapshot parses");
        assert_eq!(rc_mismatches, 0, "quiescent snapshot refcounts re-derive exactly");
        restored
    }

    fn stats_eq(a: &IndexStats, b: &IndexStats, what: &str) {
        assert_eq!(a.nodes, b.nodes, "{what}: nodes");
        assert_eq!(a.token_positions, b.token_positions, "{what}: positions");
        assert_eq!(a.heap_bytes, b.heap_bytes, "{what}: heap bytes");
        assert_eq!(a.pool_segments, b.pool_segments, "{what}: pool segments");
        assert_eq!(a.pool_tokens, b.pool_tokens, "{what}: pool tokens");
        assert_eq!(a.link_rebuilds, b.link_rebuilds, "{what}: link rebuilds");
    }

    #[test]
    fn snapshot_roundtrip_all_substrates_bit_identical() {
        // The ISSUE's acceptance property at the drafter layer: for every
        // substrate, snapshot → load yields bit-identical draft_from
        // outputs and IndexStats versus the uninterrupted drafter — and
        // keeps behaving identically as more history arrives.
        for substrate in ["window", "tree", "array"] {
            let mut d =
                SuffixDrafter::with_substrate(HistoryScope::Problem, substrate, 4, 8, 16, false);
            for e in 0..3 {
                d.roll_epoch(e);
                for p in 1..4 {
                    let t: Vec<u32> =
                        (0..30).map(|i| (i * (p + 2) + e) % 17).collect();
                    d.observe_rollout(&rollout(p, e, t));
                }
            }
            let mut r = roundtrip(&d);
            assert_eq!(r.substrate(), substrate);
            assert_eq!(r.epoch(), d.epoch());
            assert_eq!(r.indexed_tokens(), d.indexed_tokens(), "substrate {substrate}");
            stats_eq(&r.index_stats(), &d.index_stats(), substrate);
            for p in 1..4 {
                for ctx_len in 2u32..6 {
                    let ctx: Vec<u32> = (0..ctx_len).map(|i| (i * (p + 2) + 2) % 17).collect();
                    let a = d.draft(100, p, &ctx, 6);
                    let b = r.draft(100, p, &ctx, 6);
                    assert_eq!(a.tokens, b.tokens, "substrate {substrate} p{p}");
                    assert_eq!(a.confidence, b.confidence, "substrate {substrate} p{p}");
                    assert_eq!(a.match_len, b.match_len, "substrate {substrate} p{p}");
                }
            }
            // Post-restore divergence check: identical further history
            // must keep the two bit-identical (windows roll, epochs age).
            for dd in [&mut d, &mut r] {
                dd.roll_epoch(3);
                dd.observe_rollout(&rollout(1, 3, vec![1, 2, 3, 4, 5, 6]));
            }
            assert_eq!(
                d.draft(7, 1, &[1, 2, 3], 3).tokens,
                r.draft(7, 1, &[1, 2, 3], 3).tokens,
                "substrate {substrate}: post-restore inserts stay identical"
            );
            stats_eq(&r.index_stats(), &d.index_stats(), substrate);
        }
    }

    #[test]
    fn snapshot_roundtrip_trie_request_local_substrate() {
        // The fourth substrate (the plain counting trie) backs the
        // request-local indexes; its persistence path is exercised through
        // the global-scope drafter too, but pin it directly.
        use crate::drafter::DraftSource;
        use crate::store::wire::{Reader, Writer};
        let pool = crate::suffix::SharedPool::new();
        let mut idx = SuffixTrieIndex::with_pool(12, pool.clone());
        idx.insert(&[5, 6, 7, 8, 5, 6, 9]);
        idx.insert(&[5, 6, 7, 8]);
        let mut w = Writer::new();
        pool.save_state(&mut w);
        DraftSource::save_state(&idx, &mut w);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let (pool2, recorded) = crate::suffix::SharedPool::load_state(&mut r).unwrap();
        let mut restored = SuffixTrieIndex::with_pool(12, pool2.clone());
        DraftSource::load_state(&mut restored, &mut r).unwrap();
        assert_eq!(pool2.reconcile_recorded(&recorded), 0);
        assert_eq!(restored.tokens_indexed(), idx.tokens_indexed());
        assert_eq!(restored.rollouts(), idx.rollouts());
        assert_eq!(restored.node_count(), idx.node_count());
        assert_eq!(restored.approx_bytes(), idx.approx_bytes());
        let a = DraftSource::draft_from(&idx, &[5, 6], 8, 4);
        let b = DraftSource::draft_from(&restored, &[5, 6], 8, 4);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.confidence, b.confidence);
        assert_eq!(a.match_len, b.match_len);
    }

    #[test]
    fn snapshot_roundtrip_preserves_router_and_scopes() {
        // Router + global scope + counters all survive the trip; routing
        // decisions are identical afterwards.
        let mut d = SuffixDrafter::configured(
            HistoryScope::GlobalRequest,
            "window",
            8,
            8,
            16,
            true,
            8,
        );
        d.observe_rollout(&rollout(1, 0, vec![5, 6, 7, 8]));
        d.observe_rollout(&rollout(2, 0, vec![5, 6, 20, 21]));
        let _ = d.draft(1, 1, &[5, 6, 7], 1); // bump hit/miss counters
        let mut r = roundtrip(&d);
        assert_eq!(r.scope(), d.scope());
        assert_eq!((r.local_hits, r.shard_hits, r.misses), (d.local_hits, d.shard_hits, d.misses));
        // Router redirects a foreign problem id to the matching shard in
        // both drafters.
        assert_eq!(
            d.draft(9, 42, &[5, 6, 7], 1).tokens,
            r.draft(9, 42, &[5, 6, 7], 1).tokens
        );
        assert_eq!(r.draft(9, 42, &[5, 6, 7], 1).tokens, vec![8]);
        stats_eq(&r.index_stats(), &d.index_stats(), "router roundtrip");
    }

    #[test]
    fn load_state_rejects_parameter_drift() {
        use crate::drafter::Drafter;
        use crate::store::wire::StoreError;
        let mut d = SuffixDrafter::with_substrate(HistoryScope::Problem, "window", 4, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3]));
        let bytes = d.save_state();
        // Same config: accepted.
        let mut same =
            SuffixDrafter::with_substrate(HistoryScope::Problem, "window", 4, 8, 16, false);
        same.load_state(&bytes).unwrap();
        assert_eq!(same.draft(1, 1, &[1, 2], 1).tokens, vec![3]);
        // Different window / substrate / scope / router: all refused with
        // Mismatch, leaving the receiver untouched (cold).
        let mismatches: Vec<SuffixDrafter> = vec![
            SuffixDrafter::with_substrate(HistoryScope::Problem, "window", 8, 8, 16, false),
            SuffixDrafter::with_substrate(HistoryScope::Problem, "tree", 4, 8, 16, false),
            SuffixDrafter::with_substrate(HistoryScope::GlobalRequest, "window", 4, 8, 16, false),
            SuffixDrafter::with_substrate(HistoryScope::Problem, "window", 4, 8, 16, true),
        ];
        for mut m in mismatches {
            match m.load_state(&bytes) {
                Err(StoreError::Mismatch(_)) => {}
                other => panic!("expected Mismatch, got {other:?}"),
            }
            assert!(m.draft(1, 1, &[1, 2], 1).is_empty(), "receiver stays cold");
        }
        // Corrupt payloads are versioned errors, never panics.
        assert!(matches!(
            SuffixDrafter::from_state(&bytes[..bytes.len() / 2]),
            Err(StoreError::Truncated) | Err(StoreError::Corrupt(_))
        ));
        assert!(SuffixDrafter::from_state(b"not-a-snapshot").is_err());
    }

    #[test]
    fn wal_replay_reaches_snapshot_plus_tail_state() {
        // snapshot(at epoch 1) + WAL records for epoch 2 must equal the
        // uninterrupted drafter — the mid-stream recovery equation, with a
        // window roll (eviction) inside the recorded tail.
        use crate::store::{replay_wal, WalRecord};
        let build = |interrupt: bool| -> SuffixDrafter {
            let mut d =
                SuffixDrafter::with_substrate(HistoryScope::Problem, "window", 2, 8, 16, false);
            d.roll_epoch(0);
            d.observe_rollout(&rollout(1, 0, vec![1, 2, 3, 4]));
            d.roll_epoch(1);
            d.observe_rollout(&rollout(1, 1, vec![1, 2, 9, 9]));
            let mut d = if interrupt {
                SuffixDrafter::from_state(&d.save_state()).unwrap()
            } else {
                d
            };
            // The tail that would live in the WAL after the snapshot.
            let tail = [
                WalRecord::RollEpoch(2),
                WalRecord::Absorb { problem: 1, epoch: 2, tokens: vec![1, 2, 9, 5] },
                WalRecord::RollEpoch(3),
            ];
            replay_wal(&mut d, &tail);
            d
        };
        let mut live = build(false);
        let mut resumed = build(true);
        // Epoch 0 evicted by the window=2 roll to epoch 3 in both.
        assert_eq!(
            live.draft(1, 1, &[1, 2], 2).tokens,
            resumed.draft(1, 1, &[1, 2], 2).tokens
        );
        assert_eq!(resumed.draft(1, 1, &[1, 2], 2).tokens, vec![9, 5]);
        assert_eq!(resumed.indexed_tokens(), live.indexed_tokens());
    }

    #[test]
    fn drafter_snapshot_matches_serial_draft_and_counters() {
        // Two identically-built drafters per (scope, substrate, router)
        // combo: one drafts serially (the locked single-threaded
        // reference), the other through a published snapshot +
        // apply_draft_outcomes. Drafts must be bit-identical and the
        // hit/miss counters must end equal.
        use crate::drafter::DraftOutcome;
        let combos = [
            (HistoryScope::Problem, "window", false),
            (HistoryScope::Problem, "tree", true),
            (HistoryScope::ProblemRequest, "window", true),
            (HistoryScope::GlobalRequest, "array", false),
        ];
        for (scope, substrate, router) in combos {
            let build = || {
                let mut d =
                    SuffixDrafter::with_substrate(scope, substrate, 4, 8, 16, router);
                for e in 0..2 {
                    d.roll_epoch(e);
                    for p in 1..4u32 {
                        let t: Vec<u32> = (0..24).map(|i| (i * (p + 2) + e) % 11).collect();
                        d.observe_rollout(&rollout(p, e, t));
                    }
                }
                d.observe_partial(70, 1, &[10, 11, 12, 13, 10, 11, 12]);
                d
            };
            let mut serial = build();
            let mut conc = build();
            let snap = conc.snapshot().expect("suffix drafter publishes a snapshot");
            assert_eq!(snap.epoch(), serial.epoch());
            let mut probes: Vec<(RequestId, ProblemId, Vec<u32>)> = vec![
                (70, 1, vec![10, 11, 12]),   // request-local repetition
                (100, 9, vec![1, 2, 3]),     // unknown problem → router or miss
                (101, 2, vec![9, 9]),        // junk context
            ];
            for p in 1..4u32 {
                probes.push((102 + p as u64, p, (0..3).map(|i| (i * (p + 2) + 1) % 11).collect()));
                probes.push((110 + p as u64, p, (2..5).map(|i| (i * (p + 2)) % 11).collect()));
            }
            let (mut local, mut shard, mut miss) = (0u64, 0u64, 0u64);
            for (req, problem, ctx) in &probes {
                let a = serial.draft(*req, *problem, ctx, 5);
                let (b, outcome) = snap.draft(*req, *problem, ctx, 5);
                let tag = format!("{scope:?}/{substrate}/router={router} ctx {ctx:?}");
                assert_eq!(a.tokens, b.tokens, "{tag}");
                assert_eq!(a.confidence, b.confidence, "{tag}");
                assert_eq!(a.match_len, b.match_len, "{tag}");
                match outcome {
                    DraftOutcome::Local => local += 1,
                    DraftOutcome::Shard => shard += 1,
                    DraftOutcome::Miss => miss += 1,
                    DraftOutcome::Skipped => panic!("{tag}: non-empty probe skipped"),
                }
            }
            // Zero-budget / empty-context short-circuit matches the serial
            // early return: no draft, no counter movement.
            assert!(matches!(snap.draft(1, 1, &[1, 2], 0).1, DraftOutcome::Skipped));
            assert!(matches!(snap.draft(1, 1, &[], 5).1, DraftOutcome::Skipped));
            conc.apply_draft_outcomes(local, shard, miss);
            assert_eq!(
                (conc.local_hits, conc.shard_hits, conc.misses),
                (serial.local_hits, serial.shard_hits, serial.misses),
                "{scope:?}/{substrate}/router={router}: outcome counts reconcile"
            );
        }
    }

    #[test]
    fn drafter_snapshot_is_cached_and_invalidated() {
        let mut d = SuffixDrafter::new(HistoryScope::Problem, 4, 8, 16, false);
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 3, 4]));
        let a = d.snapshot().unwrap();
        let b = d.snapshot().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "no mutation → cached Arc");
        d.observe_rollout(&rollout(1, 0, vec![1, 2, 9, 9]));
        let c = d.snapshot().unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "absorb invalidates");
        // The old snapshot is frozen on the pre-absorb history...
        assert_eq!(a.draft(5, 1, &[1, 2], 2).0.tokens, vec![3, 4]);
        // ...while the new one matches the live serial answer.
        assert_eq!(c.draft(5, 1, &[1, 2], 2).0.tokens, d.draft(5, 1, &[1, 2], 2).tokens);
        d.roll_epoch(1);
        let e = d.snapshot().unwrap();
        assert!(!Arc::ptr_eq(&c, &e), "epoch roll invalidates");
        assert_eq!(e.epoch(), 1);
    }

    #[test]
    fn alternative_substrates_draft_through_same_routing() {
        // Fig. 5's alternatives behind the same drafter: scope rules and
        // min-match thresholds apply regardless of the substrate.
        for substrate in ["tree", "array"] {
            let mut d = SuffixDrafter::with_substrate(
                HistoryScope::Problem,
                substrate,
                8,
                8,
                16,
                false,
            );
            assert_eq!(d.substrate(), substrate);
            d.observe_rollout(&rollout(1, 0, vec![1, 2, 3, 4, 5]));
            let draft = d.draft(100, 1, &[1, 2], 3);
            assert_eq!(draft.tokens, vec![3, 4, 5], "substrate {substrate}");
            assert!(draft.match_len >= 2, "substrate {substrate}");
            // Per-problem isolation holds for every substrate.
            assert!(d.draft(101, 2, &[1, 2], 3).is_empty(), "substrate {substrate}");
            // A 1-token coincidental match is below min_match: rejected.
            assert!(d.draft(102, 1, &[9, 2], 3).is_empty(), "substrate {substrate}");
        }
    }
}

//! Rollout history store + cross-epoch similarity analysis (Fig. 2).
//!
//! The paper's Insight-2 rests on two measurements over stored rollouts:
//! the per-iteration *n-gram reuse ratio* (how much of each new rollout
//! already appeared in the previous iteration's rollouts for the same
//! problem) and the *pairwise epoch similarity matrix* (block structure
//! near the diagonal ⇒ recency bias ⇒ sliding windows).

use std::collections::{HashMap, HashSet};

use crate::tokens::{Epoch, ProblemId, Rollout, TokenId};

/// The n-gram set of a corpus, built ONCE and queried per text. The Fig. 2
/// metrics used to rebuild this set inside every per-text call, which made
/// `set_similarity` (and hence the epoch similarity matrix) quadratic in
/// corpus size; hoisting the set makes them linear.
fn gram_set<'a>(corpus: &[&'a [TokenId]], n: usize) -> HashSet<&'a [TokenId]> {
    let mut grams: HashSet<&[TokenId]> = HashSet::new();
    for seq in corpus {
        if seq.len() >= n {
            for w in seq.windows(n) {
                grams.insert(w);
            }
        }
    }
    grams
}

/// Fraction of `text`'s n-grams present in a prebuilt gram set.
fn reuse_against(grams: &HashSet<&[TokenId]>, text: &[TokenId], n: usize) -> f64 {
    if text.len() < n {
        return 0.0;
    }
    let total = text.len() - n + 1;
    let hit = text.windows(n).filter(|w| grams.contains(*w)).count();
    hit as f64 / total as f64
}

/// N-gram reuse: fraction of `text`'s n-grams that occur anywhere in
/// `corpus` (the Fig. 2-left metric). One-shot API — callers scoring many
/// texts against the same corpus go through the hoisted gram set instead.
pub fn ngram_reuse(corpus: &[&[TokenId]], text: &[TokenId], n: usize) -> f64 {
    if text.len() < n {
        return 0.0;
    }
    reuse_against(&gram_set(corpus, n), text, n)
}

/// Symmetric similarity between two rollout sets: mean of directional
/// n-gram reuse both ways. Each direction builds its gram set ONCE —
/// linear in total corpus size, not |from| × |to| (values are pinned
/// identical to the per-text-rebuild definition by a regression test).
pub fn set_similarity(a: &[&[TokenId]], b: &[&[TokenId]], n: usize) -> f64 {
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let dir = |from: &[&[TokenId]], to: &[&[TokenId]]| -> f64 {
        let grams = gram_set(from, n);
        let vals: Vec<f64> = to.iter().map(|t| reuse_against(&grams, t, n)).collect();
        crate::util::stats::mean(&vals)
    };
    0.5 * (dir(a, b) + dir(b, a))
}

/// Store of completed rollouts, indexed by (problem, epoch).
#[derive(Debug, Default)]
pub struct RolloutHistory {
    by_problem_epoch: HashMap<(ProblemId, Epoch), Vec<Vec<TokenId>>>,
    epochs: Vec<Epoch>,
}

impl RolloutHistory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, r: &Rollout) {
        if !self.epochs.contains(&r.epoch) {
            self.epochs.push(r.epoch);
            self.epochs.sort_unstable();
        }
        self.by_problem_epoch
            .entry((r.problem, r.epoch))
            .or_default()
            .push(r.tokens.clone());
    }

    pub fn epochs(&self) -> &[Epoch] {
        &self.epochs
    }

    pub fn rollouts(&self, problem: ProblemId, epoch: Epoch) -> Vec<&[TokenId]> {
        self.by_problem_epoch
            .get(&(problem, epoch))
            .map(|v| v.iter().map(|x| x.as_slice()).collect())
            .unwrap_or_default()
    }

    fn epoch_rollouts(&self, epoch: Epoch) -> Vec<(ProblemId, &[TokenId])> {
        self.by_problem_epoch
            .iter()
            .filter(|((_, e), _)| *e == epoch)
            .flat_map(|((p, _), v)| v.iter().map(move |x| (*p, x.as_slice())))
            .collect()
    }

    /// Fig. 2-left series: for each epoch e > first, the mean per-problem
    /// reuse of epoch-e rollouts against epoch-(e−1) rollouts.
    pub fn reuse_per_iteration(&self, n: usize) -> Vec<(Epoch, f64)> {
        let mut out = Vec::new();
        for w in self.epochs.windows(2) {
            let (prev, cur) = (w[0], w[1]);
            let mut vals = Vec::new();
            for ((p, e), texts) in &self.by_problem_epoch {
                if *e != cur {
                    continue;
                }
                let prev_set = self.rollouts(*p, prev);
                if prev_set.is_empty() {
                    continue;
                }
                // Gram set hoisted: one build per (problem, epoch) pair,
                // not one per scored rollout.
                let grams = gram_set(&prev_set, n);
                for t in texts {
                    vals.push(reuse_against(&grams, t, n));
                }
            }
            out.push((cur, crate::util::stats::mean(&vals)));
        }
        out
    }

    /// Fig. 2-right: pairwise epoch similarity matrix (problem-matched).
    pub fn epoch_similarity_matrix(&self, n: usize) -> Vec<Vec<f64>> {
        let es = self.epochs.clone();
        let mut m = vec![vec![0.0; es.len()]; es.len()];
        for (i, &ei) in es.iter().enumerate() {
            for (j, &ej) in es.iter().enumerate() {
                if j < i {
                    m[i][j] = m[j][i];
                    continue;
                }
                // Problem-matched similarity, averaged over problems present
                // in both epochs.
                let probs: HashSet<ProblemId> = self
                    .epoch_rollouts(ei)
                    .iter()
                    .map(|(p, _)| *p)
                    .collect();
                let mut vals = Vec::new();
                for p in probs {
                    let a = self.rollouts(p, ei);
                    let b = self.rollouts(p, ej);
                    if !a.is_empty() && !b.is_empty() {
                        vals.push(set_similarity(&a, &b, n));
                    }
                }
                m[i][j] = crate::util::stats::mean(&vals);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ro(problem: ProblemId, epoch: Epoch, tokens: Vec<TokenId>) -> Rollout {
        Rollout {
            problem,
            epoch,
            step: 0,
            tokens,
            reward: 0.0,
        }
    }

    #[test]
    fn ngram_reuse_basics() {
        let c1 = [1u32, 2, 3, 4, 5];
        let corpus: Vec<&[u32]> = vec![&c1];
        assert!((ngram_reuse(&corpus, &[1, 2, 3], 3) - 1.0).abs() < 1e-12);
        assert_eq!(ngram_reuse(&corpus, &[7, 8, 9], 3), 0.0);
        // Half the 2-grams of [1,2,9,9]: (1,2) yes, (2,9) no, (9,9) no.
        assert!((ngram_reuse(&corpus, &[1, 2, 9, 9], 2) - 1.0 / 3.0).abs() < 1e-12);
        // Text shorter than n.
        assert_eq!(ngram_reuse(&corpus, &[1], 3), 0.0);
    }

    #[test]
    fn set_similarity_matches_per_text_rebuild_definition() {
        // Regression pin for the gram-set hoist: the linear-time
        // set_similarity must produce EXACTLY the values of the original
        // definition, which rebuilt `from`'s n-gram set once per `to`
        // element via ngram_reuse.
        let slow = |a: &[&[u32]], b: &[&[u32]], n: usize| -> f64 {
            if a.is_empty() || b.is_empty() {
                return 0.0;
            }
            let dir = |from: &[&[u32]], to: &[&[u32]]| -> f64 {
                let vals: Vec<f64> = to.iter().map(|t| ngram_reuse(from, t, n)).collect();
                crate::util::stats::mean(&vals)
            };
            0.5 * (dir(a, b) + dir(b, a))
        };
        let mut rng = crate::util::rng::Rng::seed_from_u64(11);
        for case in 0..32 {
            let gen = |rng: &mut crate::util::rng::Rng| -> Vec<Vec<u32>> {
                (0..1 + rng.below(4))
                    .map(|_| (0..rng.below(30)).map(|_| rng.below(6) as u32).collect())
                    .collect()
            };
            let (sa, sb) = (gen(&mut rng), gen(&mut rng));
            let a: Vec<&[u32]> = sa.iter().map(|v| v.as_slice()).collect();
            let b: Vec<&[u32]> = sb.iter().map(|v| v.as_slice()).collect();
            for n in 1..4 {
                let fast = set_similarity(&a, &b, n);
                let reference = slow(&a, &b, n);
                assert!(
                    (fast - reference).abs() < 1e-15 || fast == reference,
                    "case {case} n {n}: {fast} != {reference}"
                );
            }
        }
    }

    #[test]
    fn identical_sets_have_similarity_one() {
        let a1 = [1u32, 2, 3, 4];
        let a: Vec<&[u32]> = vec![&a1];
        assert!((set_similarity(&a, &a, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn similarity_decays_with_distance_under_drift() {
        // Simulate drift: each epoch mutates a couple of tokens.
        let mut h = RolloutHistory::new();
        let mut base: Vec<u32> = (0..40).map(|i| i % 7).collect();
        let mut rng = crate::util::rng::Rng::seed_from_u64(5);
        for e in 0..8 {
            h.add(&ro(1, e, base.clone()));
            for _ in 0..6 {
                let i = rng.below(base.len());
                base[i] = rng.below(7) as u32;
            }
        }
        let m = h.epoch_similarity_matrix(3);
        // Diagonal is maximal; similarity to epoch 0 decays.
        assert!(m[0][0] > 0.99);
        assert!(m[0][1] > m[0][6], "recency structure expected: {:?}", m[0]);
    }

    #[test]
    fn reuse_per_iteration_rises_when_policy_stabilizes() {
        let mut h = RolloutHistory::new();
        // Epochs 0/1 unrelated; epochs 1/2 identical.
        h.add(&ro(1, 0, (0..30).map(|i| i % 5).collect()));
        h.add(&ro(1, 1, (0..30).map(|i| (i * 3 + 1) % 5).collect()));
        h.add(&ro(1, 2, (0..30).map(|i| (i * 3 + 1) % 5).collect()));
        let series = h.reuse_per_iteration(4);
        assert_eq!(series.len(), 2);
        assert!(series[1].1 > series[0].1);
        assert!((series[1].1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn matrix_is_symmetric() {
        let mut h = RolloutHistory::new();
        for e in 0..4 {
            h.add(&ro(1, e, (0..20).map(|i| (i + e as u32) % 6).collect()));
            h.add(&ro(2, e, (0..20).map(|i| (i * 2 + e as u32) % 6).collect()));
        }
        let m = h.epoch_similarity_matrix(2);
        for i in 0..4 {
            for j in 0..4 {
                assert!((m[i][j] - m[j][i]).abs() < 1e-12);
            }
        }
    }
}

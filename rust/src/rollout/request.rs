//! Rollout request state machine.

use crate::spec::LengthClass;
use crate::tokens::{ProblemId, RequestId, TokenId};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Pending,
    Active,
    /// Finished by emitting EOS.
    FinishedEos,
    /// Finished by hitting the generation cap.
    FinishedLength,
}

#[derive(Debug)]
pub struct RolloutRequest {
    pub id: RequestId,
    pub problem: ProblemId,
    /// Prompt + committed generation in ONE contiguous buffer, so the
    /// per-round decode context is a slice (`context()`), not a clone —
    /// re-materializing the context each verification round made the hot
    /// loop O(len²) per rollout (see EXPERIMENTS.md §Perf).
    tokens: Vec<TokenId>,
    prompt_len: usize,
    pub state: RequestState,
    /// Private sampling stream — forked per request so batching order can
    /// never change any request's randomness.
    pub rng: Rng,
    pub init_class: LengthClass,
    /// Rounds this request participated in (diagnostics).
    pub rounds: u32,
    /// Draft tokens proposed / accepted for this request (diagnostics).
    pub proposed: u64,
    pub accepted: u64,
}

impl RolloutRequest {
    pub fn new(
        id: RequestId,
        problem: ProblemId,
        prompt: Vec<TokenId>,
        rng: Rng,
        init_class: LengthClass,
    ) -> Self {
        let prompt_len = prompt.len();
        RolloutRequest {
            id,
            problem,
            tokens: prompt,
            prompt_len,
            state: RequestState::Pending,
            rng,
            init_class,
            rounds: 0,
            proposed: 0,
            accepted: 0,
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(
            self.state,
            RequestState::FinishedEos | RequestState::FinishedLength
        )
    }

    /// Full decode context (prompt + committed generation) — zero-copy.
    pub fn context(&self) -> &[TokenId] {
        &self.tokens
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    pub fn generated(&self) -> &[TokenId] {
        &self.tokens[self.prompt_len..]
    }

    pub fn gen_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// Commit verified tokens; returns how many were actually committed
    /// (truncation at EOS or at the generation cap ends the request).
    pub fn commit(&mut self, tokens: &[TokenId], eos: TokenId, max_new_tokens: usize) -> usize {
        let mut committed = 0;
        for &t in tokens {
            self.tokens.push(t);
            committed += 1;
            if t == eos {
                self.state = RequestState::FinishedEos;
                return committed;
            }
            if self.gen_len() >= max_new_tokens {
                self.state = RequestState::FinishedLength;
                return committed;
            }
        }
        committed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> RolloutRequest {
        RolloutRequest::new(1, 2, vec![9, 8], Rng::seed_from_u64(1), LengthClass::Medium)
    }

    #[test]
    fn commit_stops_at_eos() {
        let mut r = req();
        let n = r.commit(&[1, 2, 63, 4], 63, 100);
        assert_eq!(n, 3);
        assert_eq!(r.state, RequestState::FinishedEos);
        assert_eq!(r.generated(), &[1, 2, 63]);
    }

    #[test]
    fn commit_stops_at_cap() {
        let mut r = req();
        let n = r.commit(&[1, 2, 3, 4, 5], 63, 3);
        assert_eq!(n, 3);
        assert_eq!(r.state, RequestState::FinishedLength);
    }

    #[test]
    fn context_concatenates() {
        let mut r = req();
        r.commit(&[5], 63, 10);
        assert_eq!(r.context(), &[9, 8, 5]);
        assert_eq!(r.gen_len(), 1);
        assert!(!r.is_done());
    }
}

//! Rollout request state machine, plus the serializable freeze format
//! ([`RequestCheckpoint`]) that lets an in-flight request migrate between
//! workers at a verification-round boundary and resume bit-identically.

use crate::spec::LengthClass;
use crate::store::wire::{checksum, len_u32, Reader, StoreError, Writer};
use crate::tokens::{ProblemId, RequestId, TokenId};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Pending,
    Active,
    /// Finished by emitting EOS.
    FinishedEos,
    /// Finished by hitting the generation cap.
    FinishedLength,
}

#[derive(Debug)]
pub struct RolloutRequest {
    pub id: RequestId,
    pub problem: ProblemId,
    /// Prompt + committed generation in ONE contiguous buffer, so the
    /// per-round decode context is a slice (`context()`), not a clone —
    /// re-materializing the context each verification round made the hot
    /// loop O(len²) per rollout (see EXPERIMENTS.md §Perf).
    tokens: Vec<TokenId>,
    prompt_len: usize,
    pub state: RequestState,
    /// Private sampling stream — forked per request so batching order can
    /// never change any request's randomness.
    pub rng: Rng,
    pub init_class: LengthClass,
    /// Rounds this request participated in (diagnostics).
    pub rounds: u32,
    /// Draft tokens proposed / accepted for this request (diagnostics).
    pub proposed: u64,
    pub accepted: u64,
    /// Length of each committed token run, in commit order. The drafter's
    /// per-request scope absorbs committed runs chunk-at-a-time
    /// (`observe_partial`), and chunks never cross-connect inside the
    /// request-local index — so reconstructing that scope on another worker
    /// requires replaying the *same* chunk boundaries, not just the same
    /// token stream. This is the checkpoint's record of those boundaries.
    commit_chunks: Vec<u32>,
}

impl RolloutRequest {
    pub fn new(
        id: RequestId,
        problem: ProblemId,
        prompt: Vec<TokenId>,
        rng: Rng,
        init_class: LengthClass,
    ) -> Self {
        let prompt_len = prompt.len();
        RolloutRequest {
            id,
            problem,
            tokens: prompt,
            prompt_len,
            state: RequestState::Pending,
            rng,
            init_class,
            rounds: 0,
            proposed: 0,
            accepted: 0,
            commit_chunks: Vec::new(),
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(
            self.state,
            RequestState::FinishedEos | RequestState::FinishedLength
        )
    }

    /// Full decode context (prompt + committed generation) — zero-copy.
    pub fn context(&self) -> &[TokenId] {
        &self.tokens
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    pub fn generated(&self) -> &[TokenId] {
        &self.tokens[self.prompt_len..]
    }

    pub fn gen_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// Commit verified tokens; returns how many were actually committed
    /// (truncation at EOS or at the generation cap ends the request).
    pub fn commit(&mut self, tokens: &[TokenId], eos: TokenId, max_new_tokens: usize) -> usize {
        let mut committed = 0;
        for &t in tokens {
            self.tokens.push(t);
            committed += 1;
            if t == eos {
                self.state = RequestState::FinishedEos;
                break;
            }
            if self.gen_len() >= max_new_tokens {
                self.state = RequestState::FinishedLength;
                break;
            }
        }
        if committed > 0 {
            self.commit_chunks.push(len_u32(committed));
        }
        committed
    }

    /// Per-round committed run lengths (see the field doc).
    pub fn commit_chunks(&self) -> &[u32] {
        &self.commit_chunks
    }

    /// Freeze this request into a serializable checkpoint. Only meaningful
    /// at a verification-round boundary (nothing half-committed); the
    /// engine enforces that by checkpointing between rounds.
    pub fn checkpoint(&self, degraded: bool) -> RequestCheckpoint {
        RequestCheckpoint {
            origin_id: self.id,
            problem: self.problem,
            prompt: self.tokens[..self.prompt_len].to_vec(),
            generated: self.tokens[self.prompt_len..].to_vec(),
            commit_chunks: self.commit_chunks.clone(),
            rng_state: self.rng.state(),
            init_class: self.init_class,
            rounds: self.rounds,
            proposed: self.proposed,
            accepted: self.accepted,
            degraded,
        }
    }

    /// Thaw a checkpoint on a (possibly different) worker. The resuming
    /// engine assigns a fresh local `id` — request ids are engine-local and
    /// collide across workers — while the RNG stream, committed tokens and
    /// acceptance bookkeeping continue exactly where the origin froze them.
    pub fn from_checkpoint(id: RequestId, ckpt: &RequestCheckpoint) -> RolloutRequest {
        let mut tokens =
            Vec::with_capacity(ckpt.prompt.len() + ckpt.generated.len());
        tokens.extend_from_slice(&ckpt.prompt);
        tokens.extend_from_slice(&ckpt.generated);
        RolloutRequest {
            id,
            problem: ckpt.problem,
            tokens,
            prompt_len: ckpt.prompt.len(),
            state: RequestState::Pending,
            rng: Rng::from_state(ckpt.rng_state),
            init_class: ckpt.init_class,
            rounds: ckpt.rounds,
            proposed: ckpt.proposed,
            accepted: ckpt.accepted,
            commit_chunks: ckpt.commit_chunks.clone(),
        }
    }
}

/// Magic tag heading every serialized checkpoint.
pub const CKPT_MAGIC: &str = "das-ckpt-v1";

fn class_to_u8(c: LengthClass) -> u8 {
    match c {
        LengthClass::Short => 0,
        LengthClass::Medium => 1,
        LengthClass::Long => 2,
    }
}

fn class_from_u8(v: u8) -> Result<LengthClass, StoreError> {
    match v {
        0 => Ok(LengthClass::Short),
        1 => Ok(LengthClass::Medium),
        2 => Ok(LengthClass::Long),
        _ => Err(StoreError::Corrupt(format!("unknown length class {v}"))),
    }
}

/// Everything needed to resume an in-flight request bit-identically on a
/// different worker: the token state, the private RNG cursor, the
/// acceptance bookkeeping the `LengthPolicy` learns from, and the commit
/// chunk boundaries that reconstruct the per-request drafter scope.
///
/// Serialized with the `das-store-v1` wire codec: magic tag, FNV-1a body
/// checksum, length-guarded body. Torn or tampered bytes are rejected with
/// a [`StoreError`], never a panic — checkpoints cross a channel today but
/// the format is built to survive a disk or a socket tomorrow.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestCheckpoint {
    /// Request id on the worker that froze it (provenance/diagnostics only;
    /// ids are engine-local, so the resuming engine assigns a fresh one).
    pub origin_id: RequestId,
    pub problem: ProblemId,
    pub prompt: Vec<TokenId>,
    pub generated: Vec<TokenId>,
    /// Committed run lengths per verification round, in order.
    pub commit_chunks: Vec<u32>,
    /// Raw Xoshiro256** state — carried verbatim, never re-forked: worker
    /// seeds differ, so re-deriving the stream would change sampled output.
    pub rng_state: [u64; 4],
    pub init_class: LengthClass,
    pub rounds: u32,
    pub proposed: u64,
    pub accepted: u64,
    /// Whether the origin had already degraded this request to plain
    /// decoding (a poisoned drafter must stay degraded after migration).
    pub degraded: bool,
}

impl RequestCheckpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Writer::new();
        body.u64(self.origin_id);
        body.u32(self.problem);
        body.tokens(&self.prompt);
        body.tokens(&self.generated);
        body.tokens(&self.commit_chunks);
        for w in self.rng_state {
            body.u64(w);
        }
        body.u8(class_to_u8(self.init_class));
        body.u32(self.rounds);
        body.u64(self.proposed);
        body.u64(self.accepted);
        body.u8(u8::from(self.degraded));
        let body = body.into_bytes();
        let mut out = Writer::new();
        out.str(CKPT_MAGIC);
        out.u64(checksum(&body));
        out.usize(body.len());
        let mut bytes = out.into_bytes();
        bytes.extend_from_slice(&body);
        bytes
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<RequestCheckpoint, StoreError> {
        let mut r = Reader::new(bytes);
        r.expect_str(CKPT_MAGIC, "checkpoint magic")?;
        let want = r.u64()?;
        let len = r.count(1)?;
        let body = r.bytes(len)?;
        if checksum(body) != want {
            return Err(StoreError::Corrupt(
                "checkpoint checksum mismatch".into(),
            ));
        }
        let mut r = Reader::new(body);
        let origin_id = r.u64()?;
        let problem = r.u32()?;
        let prompt = r.tokens()?;
        let generated = r.tokens()?;
        let commit_chunks = r.tokens()?;
        let mut rng_state = [0u64; 4];
        for w in rng_state.iter_mut() {
            *w = r.u64()?;
        }
        let init_class = class_from_u8(r.u8()?)?;
        let rounds = r.u32()?;
        let proposed = r.u64()?;
        let accepted = r.u64()?;
        let degraded = match r.u8()? {
            0 => false,
            1 => true,
            v => {
                return Err(StoreError::Corrupt(format!(
                    "bad degraded flag {v}"
                )))
            }
        };
        if !r.is_empty() {
            return Err(StoreError::Corrupt(format!(
                "{} trailing bytes after checkpoint body",
                r.remaining()
            )));
        }
        // Chunk lengths must tile the generated run exactly, or the drafter
        // scope replay on the destination would diverge from the origin.
        let tiled: u64 = commit_chunks.iter().map(|&c| c as u64).sum();
        if tiled != generated.len() as u64 {
            return Err(StoreError::Corrupt(format!(
                "commit chunks cover {tiled} tokens but {} were generated",
                generated.len()
            )));
        }
        Ok(RequestCheckpoint {
            origin_id,
            problem,
            prompt,
            generated,
            commit_chunks,
            rng_state,
            init_class,
            rounds,
            proposed,
            accepted,
            degraded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> RolloutRequest {
        RolloutRequest::new(1, 2, vec![9, 8], Rng::seed_from_u64(1), LengthClass::Medium)
    }

    #[test]
    fn commit_stops_at_eos() {
        let mut r = req();
        let n = r.commit(&[1, 2, 63, 4], 63, 100);
        assert_eq!(n, 3);
        assert_eq!(r.state, RequestState::FinishedEos);
        assert_eq!(r.generated(), &[1, 2, 63]);
    }

    #[test]
    fn commit_stops_at_cap() {
        let mut r = req();
        let n = r.commit(&[1, 2, 3, 4, 5], 63, 3);
        assert_eq!(n, 3);
        assert_eq!(r.state, RequestState::FinishedLength);
    }

    #[test]
    fn context_concatenates() {
        let mut r = req();
        r.commit(&[5], 63, 10);
        assert_eq!(r.context(), &[9, 8, 5]);
        assert_eq!(r.gen_len(), 1);
        assert!(!r.is_done());
    }

    #[test]
    fn commit_records_chunk_boundaries() {
        let mut r = req();
        r.commit(&[1, 2], 63, 100);
        r.commit(&[3], 63, 100);
        r.commit(&[4, 5, 63, 7], 63, 100); // EOS truncates the run to 3
        assert_eq!(r.commit_chunks(), &[2, 1, 3]);
        assert_eq!(r.gen_len(), 6);
    }

    fn ckpt() -> RequestCheckpoint {
        let mut r = RolloutRequest::new(
            7,
            3,
            vec![10, 11, 12],
            Rng::seed_from_u64(41),
            LengthClass::Long,
        );
        r.rng.next_u64(); // advance the stream so the cursor is non-trivial
        r.commit(&[20, 21], 63, 100);
        r.commit(&[22], 63, 100);
        r.rounds = 2;
        r.proposed = 5;
        r.accepted = 3;
        r.checkpoint(true)
    }

    #[test]
    fn checkpoint_round_trip_is_identity() {
        let c = ckpt();
        let bytes = c.to_bytes();
        let back = RequestCheckpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn checkpoint_truncation_rejected_at_every_cut() {
        let bytes = ckpt().to_bytes();
        for cut in 0..bytes.len() {
            let res = RequestCheckpoint::from_bytes(&bytes[..cut]);
            assert!(res.is_err(), "cut at {cut} parsed");
        }
    }

    #[test]
    fn checkpoint_bit_flips_rejected() {
        let bytes = ckpt().to_bytes();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            // Every single-bit corruption must surface as an error — a
            // flipped magic byte, checksum word, length, or body byte.
            assert!(
                RequestCheckpoint::from_bytes(&bad).is_err(),
                "flip at {i} parsed"
            );
        }
    }

    #[test]
    fn checkpoint_rejects_untiled_chunks() {
        let mut c = ckpt();
        c.commit_chunks = vec![1]; // covers 1 of 3 generated tokens
        let err = RequestCheckpoint::from_bytes(&c.to_bytes()).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt(_)), "{err}");
    }

    #[test]
    fn thawed_request_continues_rng_and_tokens_exactly() {
        let mut orig = RolloutRequest::new(
            7,
            3,
            vec![10, 11],
            Rng::seed_from_u64(17),
            LengthClass::Medium,
        );
        orig.commit(&[30, 31], 63, 100);
        orig.rng.next_u64();
        let c = c_round_trip(&orig.checkpoint(false));
        let mut thawed = RolloutRequest::from_checkpoint(99, &c);
        assert_eq!(thawed.id, 99);
        assert_eq!(thawed.context(), orig.context());
        assert_eq!(thawed.prompt_len(), orig.prompt_len());
        assert_eq!(thawed.commit_chunks(), orig.commit_chunks());
        assert_eq!(thawed.state, RequestState::Pending);
        // The RNG stream continues where the origin stopped.
        for _ in 0..32 {
            assert_eq!(thawed.rng.next_u64(), orig.rng.next_u64());
        }
    }

    fn c_round_trip(c: &RequestCheckpoint) -> RequestCheckpoint {
        RequestCheckpoint::from_bytes(&c.to_bytes()).unwrap()
    }
}

//! Deterministic fault injection for the supervised rollout pool.
//!
//! A [`FaultPlan`] is a declarative list of failures consumed at fixed seams
//! in the coordinator/worker/engine pipeline, so chaos runs are exactly
//! reproducible: the same plan against the same config produces the same
//! panics, delays and IO failures at the same steps, every run. The paper's
//! losslessness guarantee (greedy outputs are independent of drafter and
//! scheduling state) turns that reproducibility into an oracle — a chaos run
//! must produce rollouts byte-identical to an uninterrupted control run.
//!
//! Plan syntax: semicolon-separated directives, each `kind key=value ...`:
//!
//! ```text
//! panic worker=1 step=3          # worker 1 panics on its first chunk of step 3
//! delay worker=0 step=2 ms=40    # worker 0 sleeps 40ms before that chunk
//! store-fail epoch=2             # store writes fail from epoch 2 onward
//! poison-draft step=5            # one drafter call panics at step 5
//! preempt worker=0 step=1        # worker 0 freezes + migrates its in-flight chunk at step 1
//! poison-host step=2             # one draft-reader HOST thread panics at step 2
//! kill-draftsvc step=2           # the remote draft daemon dies before step 2 drafts
//! ```
//!
//! `panic`, `delay`, `poison-draft`, `preempt`, `poison-host` and
//! `kill-draftsvc` are one-shot: a per-entry atomic flag
//! marks them fired, so a respawned worker sharing the plan (the pool hands
//! every incarnation the same `Arc<FaultPlan>`) does not re-trigger the
//! injection and panic-loop. `store-fail` is level-triggered — every store
//! write at `epoch >= N` fails, modelling a persistently sick disk — but its
//! flag is still set on first trigger so [`FaultPlan::unfired`] can audit
//! whether a plan actually exercised every seam it named.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    /// Panic worker `worker` when it receives its first chunk of `step`.
    PanicWorker { worker: usize, step: u32 },
    /// Delay worker `worker`'s first chunk of `step` by `ms` milliseconds.
    DelayWorker { worker: usize, step: u32, ms: u64 },
    /// Fail every store write (WAL append / snapshot commit) from `epoch` on.
    StoreFail { epoch: u32 },
    /// Panic one drafter call at `step` (exercises the degradation ladder).
    PoisonDraft { step: u32 },
    /// Force worker `worker` to preempt its in-flight chunk at `step`:
    /// every unfinished request is checkpointed at the next
    /// verification-round boundary and migrated to an idle peer.
    Preempt { worker: usize, step: u32 },
    /// Panic one draft-reader HOST thread at `step` — outside the
    /// per-request `catch_unwind`, so it exercises the thread-join
    /// degradation path rather than the per-request ladder.
    PoisonHost { step: u32 },
    /// Kill the remote draft daemon (`das serve-drafts`) before `step`
    /// drafts anything — the engine sends a `Die` frame, so the rest of
    /// the run exercises the timeout → retry → degrade ladder. No-op
    /// under local substrates (there is no daemon to kill).
    KillDraftsvc { step: u32 },
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Fault::PanicWorker { worker, step } => write!(f, "panic worker={worker} step={step}"),
            Fault::DelayWorker { worker, step, ms } => {
                write!(f, "delay worker={worker} step={step} ms={ms}")
            }
            Fault::StoreFail { epoch } => write!(f, "store-fail epoch={epoch}"),
            Fault::PoisonDraft { step } => write!(f, "poison-draft step={step}"),
            Fault::Preempt { worker, step } => {
                write!(f, "preempt worker={worker} step={step}")
            }
            Fault::PoisonHost { step } => write!(f, "poison-host step={step}"),
            Fault::KillDraftsvc { step } => write!(f, "kill-draftsvc step={step}"),
        }
    }
}

#[derive(Debug)]
struct Entry {
    fault: Fault,
    fired: AtomicBool,
}

/// A parsed, shareable fault plan. See the module docs for syntax and
/// firing semantics. An empty plan (the default) injects nothing and all
/// query methods are cheap constant-time misses.
#[derive(Debug, Default)]
pub struct FaultPlan {
    entries: Vec<Entry>,
    /// When set, dropping the plan skips the unfired-directive audit (the
    /// chaos harness asserts on `unfired()` itself; config validation only
    /// checks syntax and never runs the plan).
    drop_audit_disarmed: AtomicBool,
}

fn take_key(
    kv: &mut Vec<(String, u64)>,
    key: &str,
    directive: &str,
) -> Result<u64, String> {
    match kv.iter().position(|(k, _)| k == key) {
        Some(i) => Ok(kv.remove(i).1),
        None => Err(format!("fault directive '{directive}': missing '{key}='")),
    }
}

impl FaultPlan {
    /// Parse a plan string. The empty string (and any all-whitespace or
    /// empty-directive remnants like trailing `;`) yields an empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut entries = Vec::new();
        for directive in spec.split(';') {
            let directive = directive.trim();
            if directive.is_empty() {
                continue;
            }
            let mut words = directive.split_whitespace();
            let kind = words.next().unwrap_or_default();
            let mut kv: Vec<(String, u64)> = Vec::new();
            for w in words {
                let (k, v) = w.split_once('=').ok_or_else(|| {
                    format!("fault directive '{directive}': expected key=value, got '{w}'")
                })?;
                let n: u64 = v.parse().map_err(|_| {
                    format!("fault directive '{directive}': '{k}' must be a non-negative integer")
                })?;
                if kv.iter().any(|(seen, _)| seen == k) {
                    return Err(format!("fault directive '{directive}': duplicate key '{k}'"));
                }
                kv.push((k.to_string(), n));
            }
            let step_u32 = |n: u64| {
                u32::try_from(n)
                    .map_err(|_| format!("fault directive '{directive}': value {n} out of range"))
            };
            let fault = match kind {
                "panic" => Fault::PanicWorker {
                    worker: take_key(&mut kv, "worker", directive)? as usize,
                    step: step_u32(take_key(&mut kv, "step", directive)?)?,
                },
                "delay" => Fault::DelayWorker {
                    worker: take_key(&mut kv, "worker", directive)? as usize,
                    step: step_u32(take_key(&mut kv, "step", directive)?)?,
                    ms: take_key(&mut kv, "ms", directive)?,
                },
                "store-fail" => Fault::StoreFail {
                    epoch: step_u32(take_key(&mut kv, "epoch", directive)?)?,
                },
                "poison-draft" => Fault::PoisonDraft {
                    step: step_u32(take_key(&mut kv, "step", directive)?)?,
                },
                "preempt" => Fault::Preempt {
                    worker: take_key(&mut kv, "worker", directive)? as usize,
                    step: step_u32(take_key(&mut kv, "step", directive)?)?,
                },
                "poison-host" => Fault::PoisonHost {
                    step: step_u32(take_key(&mut kv, "step", directive)?)?,
                },
                "kill-draftsvc" => Fault::KillDraftsvc {
                    step: step_u32(take_key(&mut kv, "step", directive)?)?,
                },
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' (known: panic, delay, \
                         store-fail, poison-draft, preempt, poison-host, \
                         kill-draftsvc)"
                    ))
                }
            };
            if let Some((k, _)) = kv.first() {
                return Err(format!("fault directive '{directive}': unknown key '{k}'"));
            }
            entries.push(Entry {
                fault,
                fired: AtomicBool::new(false),
            });
        }
        Ok(FaultPlan {
            entries,
            drop_audit_disarmed: AtomicBool::new(false),
        })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// One-shot: true exactly once for a matching `panic` directive.
    pub fn should_panic(&self, worker: usize, step: u32) -> bool {
        self.fire_first(|f| matches!(f, Fault::PanicWorker { worker: w, step: s } if *w == worker && *s == step))
            .is_some()
    }

    /// One-shot: the delay for a matching `delay` directive, exactly once.
    pub fn delay_ms(&self, worker: usize, step: u32) -> Option<u64> {
        self.fire_first(|f| matches!(f, Fault::DelayWorker { worker: w, step: s, .. } if *w == worker && *s == step))
            .map(|f| match f {
                Fault::DelayWorker { ms, .. } => ms,
                _ => 0,
            })
    }

    /// Level-triggered: true for EVERY store write at `epoch >= N` once any
    /// `store-fail` directive covers it (a sick disk stays sick).
    pub fn store_fails(&self, epoch: u32) -> bool {
        let mut hit = false;
        for e in &self.entries {
            if let Fault::StoreFail { epoch: from } = e.fault {
                if epoch >= from {
                    // Relaxed: fired flags are independent monotonic marks,
                    // read only for reporting — no cross-flag ordering.
                    e.fired.store(true, Ordering::Relaxed);
                    hit = true;
                }
            }
        }
        hit
    }

    /// One-shot: true exactly once for a matching `poison-draft` directive.
    pub fn should_poison_draft(&self, step: u32) -> bool {
        self.fire_first(|f| matches!(f, Fault::PoisonDraft { step: s } if *s == step))
            .is_some()
    }

    /// One-shot: true exactly once for a matching `preempt` directive.
    pub fn should_preempt(&self, worker: usize, step: u32) -> bool {
        self.fire_first(|f| matches!(f, Fault::Preempt { worker: w, step: s } if *w == worker && *s == step))
            .is_some()
    }

    /// One-shot: true exactly once for a matching `poison-host` directive.
    pub fn should_poison_host(&self, step: u32) -> bool {
        self.fire_first(|f| matches!(f, Fault::PoisonHost { step: s } if *s == step))
            .is_some()
    }

    /// One-shot: true exactly once for a matching `kill-draftsvc`
    /// directive.
    pub fn should_kill_draftsvc(&self, step: u32) -> bool {
        self.fire_first(|f| matches!(f, Fault::KillDraftsvc { step: s } if *s == step))
            .is_some()
    }

    /// How many `kill-draftsvc` directives the plan carries (fired or
    /// not) — the chaos harness uses this to decide whether it must
    /// assert on the remote-degradation footprint.
    pub fn kill_draftsvc_count(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(e.fault, Fault::KillDraftsvc { .. }))
            .count()
    }

    /// How many `preempt` directives the plan carries (fired or not) — the
    /// chaos harness uses this to decide which gauges it must assert on.
    pub fn preempt_count(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(e.fault, Fault::Preempt { .. }))
            .count()
    }

    /// Directives that never fired — a chaos harness treats a plan with
    /// unfired entries as misconfigured (the seam it targeted never ran).
    pub fn unfired(&self) -> Vec<String> {
        self.entries
            .iter()
            // Relaxed: reporting-only read; firing is already quiesced by
            // the time a harness asks which directives never ran.
            .filter(|e| !e.fired.load(Ordering::Relaxed))
            .map(|e| e.fault.to_string())
            .collect()
    }

    /// Atomically consume the first unfired entry matching `pred`.
    fn fire_first(&self, pred: impl Fn(&Fault) -> bool) -> Option<Fault> {
        for e in &self.entries {
            // Relaxed swap: the one-shot claim needs atomicity, not
            // ordering — no other memory is published via the flag.
            if pred(&e.fault) && !e.fired.swap(true, Ordering::Relaxed) {
                return Some(e.fault);
            }
        }
        None
    }

    /// Turn off the drop-time unfired audit. Call this where unfired
    /// entries are checked (or expected): the chaos harness asserts on
    /// `unfired()` itself, and config validation only parses for syntax.
    pub fn disarm_drop_audit(&self) {
        // Relaxed: advisory flag consumed once at drop time.
        self.drop_audit_disarmed.store(true, Ordering::Relaxed);
    }

    /// The warning the drop audit will print, if any — exposed so tests
    /// can exercise the audit without racing on captured stderr.
    pub fn drop_warning(&self) -> Option<String> {
        // Relaxed: advisory flag, same-thread with the disarm in practice.
        if self.drop_audit_disarmed.load(Ordering::Relaxed) || self.entries.is_empty() {
            return None;
        }
        let left = self.unfired();
        if left.is_empty() {
            return None;
        }
        Some(format!(
            "WARNING: fault plan dropped with {} unfired directive(s) — the \
             seams they target never ran (typo'd worker/step, or a run too \
             short to reach them): [{}]",
            left.len(),
            left.join("; ")
        ))
    }
}

/// A fault plan names exact seams; a directive that never fires means the
/// injection silently no-opped (misaddressed worker, a step past the end of
/// the run, a typo'd `rollout.fault_plan`). Outside the chaos harness —
/// which asserts `unfired()` is empty itself — nothing else would notice,
/// so the plan audits itself on the way out.
impl Drop for FaultPlan {
    fn drop(&mut self) {
        if let Some(w) = self.drop_warning() {
            eprintln!("{w}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_plans_parse_to_nothing() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
        assert!(FaultPlan::default().is_empty());
    }

    #[test]
    fn full_plan_parses() {
        let p = FaultPlan::parse(
            "panic worker=1 step=3; delay worker=0 step=2 ms=40; \
             store-fail epoch=2; poison-draft step=5; \
             preempt worker=0 step=1; poison-host step=2; \
             kill-draftsvc step=2",
        )
        .unwrap();
        assert_eq!(p.len(), 7);
        assert_eq!(p.unfired().len(), 7);
        assert_eq!(p.preempt_count(), 1);
        assert_eq!(p.kill_draftsvc_count(), 1);
        p.disarm_drop_audit();
    }

    #[test]
    fn malformed_directives_are_rejected() {
        assert!(FaultPlan::parse("panic worker=1").is_err(), "missing step");
        assert!(FaultPlan::parse("panic worker=1 step=x").is_err(), "non-numeric");
        assert!(FaultPlan::parse("panic worker=1 step=1 step=2").is_err(), "dup key");
        assert!(FaultPlan::parse("panic worker=1 step=1 foo=2").is_err(), "unknown key");
        assert!(FaultPlan::parse("reboot worker=1").is_err(), "unknown kind");
        assert!(FaultPlan::parse("delay worker=0 step=0").is_err(), "missing ms");
    }

    #[test]
    fn one_shot_faults_fire_exactly_once() {
        let p = FaultPlan::parse("panic worker=1 step=3; poison-draft step=5").unwrap();
        assert!(!p.should_panic(0, 3), "wrong worker");
        assert!(!p.should_panic(1, 2), "wrong step");
        assert!(p.should_panic(1, 3), "first match fires");
        assert!(!p.should_panic(1, 3), "consumed — a respawn must not re-panic");
        assert!(p.should_poison_draft(5));
        assert!(!p.should_poison_draft(5));
        assert!(p.unfired().is_empty());
    }

    #[test]
    fn delay_fires_once_with_its_duration() {
        let p = FaultPlan::parse("delay worker=2 step=1 ms=40").unwrap();
        assert_eq!(p.delay_ms(2, 0), None);
        assert_eq!(p.delay_ms(2, 1), Some(40));
        assert_eq!(p.delay_ms(2, 1), None, "consumed");
    }

    #[test]
    fn store_fail_is_level_triggered_from_its_epoch() {
        let p = FaultPlan::parse("store-fail epoch=2").unwrap();
        assert!(!p.store_fails(0));
        assert!(!p.store_fails(1));
        assert_eq!(p.unfired().len(), 1, "not yet triggered");
        assert!(p.store_fails(2));
        assert!(p.store_fails(3), "stays failed — sick disks do not heal");
        assert!(p.store_fails(2), "and keeps failing at the trigger epoch");
        assert!(p.unfired().is_empty());
    }

    #[test]
    fn unfired_reports_untouched_directives() {
        let p = FaultPlan::parse("panic worker=7 step=9; delay worker=0 step=0 ms=1").unwrap();
        assert_eq!(p.delay_ms(0, 0), Some(1));
        let left = p.unfired();
        assert_eq!(left, vec!["panic worker=7 step=9".to_string()]);
        p.disarm_drop_audit();
    }

    #[test]
    fn preempt_fires_once_per_directive() {
        let p = FaultPlan::parse("preempt worker=2 step=1").unwrap();
        assert!(!p.should_preempt(1, 1), "wrong worker");
        assert!(!p.should_preempt(2, 0), "wrong step");
        assert!(p.should_preempt(2, 1));
        assert!(!p.should_preempt(2, 1), "consumed");
        assert_eq!(p.preempt_count(), 1, "count is static, not fired-state");
        assert!(p.unfired().is_empty());
    }

    #[test]
    fn poison_host_fires_once() {
        let p = FaultPlan::parse("poison-host step=2").unwrap();
        assert!(!p.should_poison_host(1));
        assert!(p.should_poison_host(2));
        assert!(!p.should_poison_host(2), "consumed");
    }

    #[test]
    fn kill_draftsvc_fires_once() {
        let p = FaultPlan::parse("kill-draftsvc step=2").unwrap();
        assert_eq!(p.kill_draftsvc_count(), 1);
        assert!(!p.should_kill_draftsvc(1), "wrong step");
        assert!(p.should_kill_draftsvc(2));
        assert!(!p.should_kill_draftsvc(2), "consumed — the daemon dies once");
        assert_eq!(p.kill_draftsvc_count(), 1, "count is static, not fired-state");
        assert!(p.unfired().is_empty());
    }

    #[test]
    fn drop_audit_warns_on_unfired_entries_only() {
        let p = FaultPlan::parse("panic worker=7 step=9; preempt worker=0 step=0").unwrap();
        let w = p.drop_warning().expect("nothing fired — must warn");
        assert!(w.contains("panic worker=7 step=9"), "{w}");
        assert!(w.contains("preempt worker=0 step=0"), "{w}");
        assert!(w.contains("2 unfired"), "{w}");
        // Fire one: the warning narrows to what is still pending.
        assert!(p.should_panic(7, 9));
        let w = p.drop_warning().expect("one entry still unfired");
        assert!(!w.contains("panic"), "{w}");
        assert!(w.contains("preempt worker=0 step=0"), "{w}");
        // Fire the rest: fully-exercised plans drop silently.
        assert!(p.should_preempt(0, 0));
        assert_eq!(p.drop_warning(), None);
    }

    #[test]
    fn drop_audit_is_silent_for_empty_and_disarmed_plans() {
        assert_eq!(FaultPlan::default().drop_warning(), None);
        assert_eq!(FaultPlan::parse("").unwrap().drop_warning(), None);
        let p = FaultPlan::parse("panic worker=1 step=1").unwrap();
        p.disarm_drop_audit();
        assert_eq!(p.drop_warning(), None, "disarmed — harness audits itself");
    }
}

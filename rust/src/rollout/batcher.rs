//! Continuous batcher (vLLM-style slot management).
//!
//! Decoding begins at full parallelism; as short sequences finish, finished
//! slots are refilled from the pending queue until the queue drains — after
//! which the effective batch *collapses* and the long tail emerges (Fig. 1).
//! The batcher guarantees conservation: every submitted request is returned
//! exactly once, finished.

use std::collections::VecDeque;

use super::request::{RequestState, RolloutRequest};

#[derive(Debug)]
pub struct Batcher {
    pending: VecDeque<RolloutRequest>,
    active: Vec<RolloutRequest>,
    finished: Vec<RolloutRequest>,
    max_batch: usize,
    submitted: usize,
}

impl Default for Batcher {
    /// Single-slot batcher. (A derived Default would set `max_batch: 0`,
    /// bypassing the `max(1)` floor in [`Batcher::new`] — a batcher that
    /// can never activate anything and strands every submission in the
    /// pending queue.)
    fn default() -> Self {
        Batcher::new(1)
    }
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        Batcher {
            pending: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            max_batch: max_batch.max(1),
            submitted: 0,
        }
    }

    pub fn submit(&mut self, req: RolloutRequest) {
        self.submitted += 1;
        self.pending.push_back(req);
    }

    /// Move finished requests out of the active set and refill from pending.
    /// Returns the requests that finished during the last round.
    pub fn recycle(&mut self) -> Vec<RolloutRequest> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].is_done() {
                done.push(self.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        while self.active.len() < self.max_batch {
            match self.pending.pop_front() {
                Some(mut r) => {
                    r.state = RequestState::Active;
                    self.active.push(r);
                }
                None => break,
            }
        }
        for r in &done {
            debug_assert!(r.is_done());
        }
        done
    }

    /// Record finished requests (callers get them from `recycle` and may
    /// hand them back for bookkeeping).
    pub fn archive(&mut self, reqs: Vec<RolloutRequest>) {
        self.finished.extend(reqs);
    }

    pub fn active_mut(&mut self) -> &mut [RolloutRequest] {
        &mut self.active
    }

    pub fn active(&self) -> &[RolloutRequest] {
        &self.active
    }

    pub fn effective_batch(&self) -> usize {
        self.active.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_drained(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    pub fn finished(&self) -> &[RolloutRequest] {
        &self.finished
    }

    pub fn take_finished(&mut self) -> Vec<RolloutRequest> {
        std::mem::take(&mut self.finished)
    }

    /// Drain every request that has NOT finished — active slots and the
    /// pending queue — handing them to the caller for checkpointing. The
    /// drained requests leave this batcher's conservation ledger (they will
    /// be re-submitted elsewhere), so `conserved()` keeps holding here.
    pub fn take_unfinished(&mut self) -> Vec<RolloutRequest> {
        let mut out: Vec<RolloutRequest> = self.active.drain(..).collect();
        out.extend(self.pending.drain(..));
        self.submitted -= out.len();
        out
    }

    /// Conservation check: submitted == active + pending + finished.
    pub fn conserved(&self) -> bool {
        self.submitted == self.active.len() + self.pending.len() + self.finished.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::LengthClass;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn req(id: u64) -> RolloutRequest {
        RolloutRequest::new(id, 0, vec![1], Rng::seed_from_u64(id), LengthClass::Medium)
    }

    #[test]
    fn fills_up_to_max_batch() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.submit(req(i));
        }
        b.recycle();
        assert_eq!(b.effective_batch(), 2);
        assert_eq!(b.pending_len(), 3);
        assert!(b.conserved());
    }

    #[test]
    fn refills_when_requests_finish() {
        let mut b = Batcher::new(2);
        for i in 0..3 {
            b.submit(req(i));
        }
        b.recycle();
        b.active_mut()[0].state = RequestState::FinishedEos;
        let done = b.recycle();
        assert_eq!(done.len(), 1);
        b.archive(done);
        assert_eq!(b.effective_batch(), 2);
        assert_eq!(b.pending_len(), 0);
        assert!(b.conserved());
    }

    #[test]
    fn drains_to_empty() {
        let mut b = Batcher::new(4);
        for i in 0..4 {
            b.submit(req(i));
        }
        b.recycle();
        for r in b.active_mut() {
            r.state = RequestState::FinishedLength;
        }
        let done = b.recycle();
        b.archive(done);
        assert!(b.is_drained());
        assert_eq!(b.finished().len(), 4);
        assert!(b.conserved());
    }

    #[test]
    fn default_batcher_can_activate_requests() {
        // Regression: the derived Default used to carry max_batch = 0 —
        // a batcher that never activated anything and stranded every
        // submission in pending forever.
        let mut b = Batcher::default();
        b.submit(req(1));
        b.recycle();
        assert_eq!(b.effective_batch(), 1);
        assert!(b.conserved());
    }

    #[test]
    fn late_resubmit_after_drain_refills_and_conserves() {
        // Fig. 1 collapse in progress: the pending queue drained, actives
        // are retiring one by one — and new work arrives mid-collapse. The
        // late submissions must flow through the same refill path, and
        // every request (old wave + late wave) must come back exactly once.
        let mut b = Batcher::new(2);
        for i in 0..3 {
            b.submit(req(i));
        }
        b.recycle();
        assert_eq!(b.pending_len(), 1);
        // Finish everything active, drain pending into the batch.
        for r in b.active_mut() {
            r.state = RequestState::FinishedEos;
        }
        let done = b.recycle();
        b.archive(done);
        assert_eq!(b.pending_len(), 0, "queue first drained");
        assert_eq!(b.effective_batch(), 1, "collapse under way");
        // Late re-submit mid-collapse.
        for i in 10..14 {
            b.submit(req(i));
        }
        assert!(b.conserved(), "conservation across the late submit");
        for r in b.active_mut() {
            r.state = RequestState::FinishedLength;
        }
        let done = b.recycle();
        b.archive(done);
        assert_eq!(b.effective_batch(), 2, "late wave refills to max_batch");
        // Drain to empty and check exactly-once delivery.
        let mut guard = 0;
        while !b.is_drained() {
            for r in b.active_mut() {
                r.state = RequestState::FinishedEos;
            }
            let done = b.recycle();
            b.archive(done);
            guard += 1;
            assert!(guard < 100, "late wave must drain");
        }
        assert!(b.conserved());
        let mut ids: Vec<u64> = b.finished().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 10, 11, 12, 13]);
    }

    #[test]
    fn max_batch_one_serializes_requests() {
        // The degenerate slot count: requests must run strictly one at a
        // time, in submission order, with conservation at every step.
        let mut b = Batcher::new(1);
        for i in 0..4 {
            b.submit(req(i));
        }
        let mut served = Vec::new();
        let mut guard = 0;
        while !b.is_drained() {
            let done = b.recycle();
            b.archive(done);
            assert!(b.effective_batch() <= 1, "never more than one active");
            assert!(b.conserved());
            if let Some(r) = b.active_mut().first_mut() {
                served.push(r.id);
                r.state = RequestState::FinishedEos;
            }
            guard += 1;
            assert!(guard < 100);
        }
        assert_eq!(served, vec![0, 1, 2, 3], "strict submission order");
        assert_eq!(b.finished().len(), 4);
    }

    #[test]
    fn take_unfinished_drains_active_and_pending_and_conserves() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.submit(req(i));
        }
        b.recycle();
        b.active_mut()[0].state = RequestState::FinishedEos;
        let done = b.recycle();
        b.archive(done);
        assert_eq!(b.finished().len(), 1);
        // 4 unfinished remain: 2 active + 2 pending.
        let taken = b.take_unfinished();
        assert_eq!(taken.len(), 4);
        assert!(b.conserved(), "ledger shrinks with the drained requests");
        assert_eq!(b.effective_batch(), 0);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.finished().len(), 1, "finished stay archived");
        let mut ids: Vec<u64> = taken.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        // Exactly the four requests that had not finished, each once.
        assert_eq!(ids.len(), 4);
        assert!(ids.iter().all(|&i| i < 5));
    }

    #[test]
    fn prop_conservation_under_random_submit_and_recycle_stream() {
        // Interleave submissions INTO a running batcher with random
        // completions and recycles: every request must be returned exactly
        // once, conservation must hold at every observation point, and the
        // batch bound must never be exceeded — including max_batch = 1 and
        // submissions that arrive after the queue has fully drained.
        prop::check(96, |g| {
            let max_batch = 1 + g.usize_in(0, 7);
            let mut b = Batcher::new(max_batch);
            let mut next_id = 0u64;
            let mut expected: Vec<u64> = Vec::new();
            let mut guard = 0;
            // Random event stream: bursts of submits, completions, drains.
            while guard < 10_000 && (next_id < 25 || !b.is_drained()) {
                guard += 1;
                if next_id < 25 && g.rng.chance(0.35) {
                    for _ in 0..1 + g.usize_in(0, 3) {
                        if next_id < 25 {
                            b.submit(req(next_id));
                            expected.push(next_id);
                            next_id += 1;
                        }
                    }
                }
                let done = b.recycle();
                for r in &done {
                    prop::require(r.is_done(), "recycle returns finished only")?;
                }
                b.archive(done);
                prop::require(b.conserved(), "conservation")?;
                prop::require(b.effective_batch() <= max_batch, "batch bound")?;
                for r in b.active_mut() {
                    if g.rng.chance(0.4) {
                        r.state = RequestState::FinishedEos;
                    }
                }
            }
            prop::require(b.is_drained(), "stream must drain")?;
            let mut got: Vec<u64> = b.finished().iter().map(|r| r.id).collect();
            got.sort_unstable();
            prop::require_eq(got, expected, "every request returned exactly once")
        });
    }

    #[test]
    fn prop_conservation_under_random_completion() {
        prop::check(96, |g| {
            let max_batch = 1 + g.usize_in(0, 7);
            let n = 1 + g.usize_in(0, 30);
            let mut b = Batcher::new(max_batch);
            let mut ids: Vec<u64> = (0..n as u64).collect();
            for i in &ids {
                b.submit(req(*i));
            }
            let mut guard = 0;
            while !b.is_drained() {
                let done = b.recycle();
                b.archive(done);
                prop::require(b.conserved(), "conservation")?;
                prop::require(b.effective_batch() <= max_batch, "batch bound")?;
                // Randomly finish some active requests.
                for r in b.active_mut() {
                    if g.rng.chance(0.4) {
                        r.state = RequestState::FinishedEos;
                    }
                }
                guard += 1;
                if guard > 10_000 {
                    return prop::require(false, "batcher did not drain");
                }
            }
            // Every request id came back exactly once.
            let mut got: Vec<u64> = b.finished().iter().map(|r| r.id).collect();
            got.sort_unstable();
            ids.sort_unstable();
            prop::require_eq(got, ids, "all requests returned once")
        });
    }
}

//! The speculative rollout engine — DAS's decode loop (Fig. 3).
//!
//! Each verification round:
//!   1. the budget policy assigns every active request a draft budget
//!      (length-aware classes §4.2.3, the Eq. 9 optimizer, uniform, or
//!      unlimited — the Fig. 12 ablation axis);
//!   2. the drafter proposes a block per request (suffix-window retrieval);
//!   3. ONE batched target forward verifies all blocks (the simulator and
//!      the PJRT backend both process `Σ(draft+1)` tokens and charge
//!      `c_base + c_tok·n`);
//!   4. exact speculative sampling commits an accepted prefix + one
//!      correction/bonus token per request — losslessness is enforced here;
//!   5. finished requests retire, the batcher refills slots, the drafter
//!      and length statistics absorb the new tokens (final length AND
//!      speculation outcome — both halves of the LPT cost key).
//!
//! With `spec.draft_threads` ≠ 1, step 2 runs on worker threads against an
//! immutable [`crate::drafter::DrafterSnapshot`] while the writer thread
//! absorbs previously finished rollouts concurrently — drafts may lag the
//! newest history by one verification round, which losslessness (step 4)
//! makes a pure perf effect, never an output change.
//!
//! The engine drives speculation only through traits: [`Drafter`] routes a
//! request to a history shard, and every shard is a
//! [`crate::drafter::DraftSource`] — the engine never names the substrate
//! (fused windowed trie, Ukkonen tree, suffix array) behind a draft. The
//! losslessness guarantee of step 4 is exactly what makes the substrate a
//! pure perf knob: at temperature 0 the committed tokens are bit-identical
//! for EVERY substrate, speculating or not (tested below).

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::batcher::Batcher;
use super::faults::FaultPlan;
use super::metrics::StepMetrics;
use super::request::{RequestCheckpoint, RolloutRequest};
use crate::config::DasConfig;
use crate::drafter::{DraftOutcome, Drafter};
use crate::model::{StepInput, TargetModel};
use crate::spec::budget::{escalate, solve as solve_budget, BudgetRequest};
use crate::spec::{verify_greedy, verify_sampling, AcceptanceEstimator, LengthClass, LengthPolicy};
use crate::store::{replay_wal, HistoryStore, StoreError, StoreStatus, WalRecord};
use crate::tokens::{Epoch, ProblemId, RequestId, Rollout, TokenId};
use crate::util::rng::Rng;

/// Draft budget policy (config `spec.budget_policy` + drafter "none").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetPolicy {
    LengthAware,
    Optimal,
    Uniform,
    Unlimited,
}

impl BudgetPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "length_aware" => Some(BudgetPolicy::LengthAware),
            "optimal" => Some(BudgetPolicy::Optimal),
            "uniform" => Some(BudgetPolicy::Uniform),
            "unlimited" => Some(BudgetPolicy::Unlimited),
            _ => None,
        }
    }
}

/// One problem's generation jobs for a step.
#[derive(Debug, Clone)]
pub struct GenJob {
    pub problem: ProblemId,
    pub prompt: Vec<TokenId>,
    pub samples: usize,
}

/// Output of one generation step.
#[derive(Debug)]
pub struct StepReport {
    pub rollouts: Vec<Rollout>,
    pub metrics: StepMetrics,
    /// Per finished request: (problem, verification rounds, accepted draft
    /// tokens). Feeds acceptance-aware LPT cost prediction in coordinators
    /// that aggregate many engines (`DataParallelRollout`).
    pub accept_obs: Vec<(ProblemId, u64, u64)>,
    /// Unfinished requests frozen at a verification-round boundary when the
    /// step was preempted (empty on a normal step). The coordinator
    /// re-dispatches these to idle workers; `RolloutEngine::resume_step`
    /// continues each one bit-identically.
    pub checkpoints: Vec<RequestCheckpoint>,
}

pub struct RolloutEngine {
    pub drafter: Box<dyn Drafter>,
    pub length_policy: LengthPolicy,
    /// Per-problem acceptance estimators feeding the Eq. 9 optimizer.
    pub acceptance: HashMap<ProblemId, AcceptanceEstimator>,
    budget_policy: BudgetPolicy,
    budget_short: usize,
    budget_medium: usize,
    budget_long: usize,
    budget_cap: usize,
    max_batch: usize,
    max_new_tokens: usize,
    temperature: f64,
    next_request: RequestId,
    epoch: Epoch,
    seed: u64,
    /// Reader threads for the snapshot draft path (`spec.draft_threads`;
    /// 0 = auto-detect, 1 = serial drafting against the live structures).
    draft_threads: usize,
    /// Persistent history store (`spec.store_dir`): WAL per absorbed
    /// rollout, snapshot every `snapshot_every` epochs. `None` when
    /// persistence is off or the drafter is stateless.
    store: Option<HistoryStore>,
    snapshot_every: Epoch,
    /// Last epoch whose roll was persisted (snapshot or WAL record) — the
    /// trainer re-announces the current epoch every step, and only the
    /// first announcement must touch the store.
    last_roll_persisted: Option<Epoch>,
    /// Deterministic fault injection (shared with the supervising pool so
    /// one-shot faults stay one-shot across worker respawns). Empty plan =
    /// every seam is a constant-time miss.
    faults: Arc<FaultPlan>,
    /// Requests whose drafter errored mid-step: speculation is disabled for
    /// the rest of the request (plain decoding — outputs unchanged at any
    /// temperature, just slower). Entries retire with their request.
    degraded: HashSet<RequestId>,
    /// Which pool slot this engine occupies (0 for standalone engines) —
    /// addressed by `preempt worker=W step=S` fault directives.
    worker_index: usize,
    /// Coordinator-armed preemption latch: when the supervising pool sets
    /// it, the decode loop freezes every unfinished request at the next
    /// verification-round boundary and returns their checkpoints. Checked
    /// with `swap(false)` so one arm triggers exactly one freeze.
    preempt_latch: Option<Arc<AtomicBool>>,
    /// Speculative-budget multiplier applied inside `resume_step` (config
    /// `spec.resume_budget_boost`, validated to [1, 8]).
    resume_budget_boost: f64,
    /// Store failures observed since the last step report (drained into
    /// `StepMetrics::store_failures` once per step — failures in
    /// `roll_epoch` happen outside any step and would otherwise be lost).
    pending_store_failures: u64,
}

/// Absorb every not-yet-indexed finished rollout into the drafter,
/// advancing the step's absorb cursor. Rollouts become durable (WAL) the
/// moment they finish, but enter the in-memory history here — either
/// right before a serial draft round (the historical visibility) or on
/// the writer thread while snapshot readers draft (the concurrent path).
fn absorb_pending(drafter: &mut dyn Drafter, rollouts: &[Rollout], absorbed: &mut usize) {
    while *absorbed < rollouts.len() {
        drafter.observe_rollout(&rollouts[*absorbed]);
        *absorbed += 1;
    }
}

impl RolloutEngine {
    pub fn new(cfg: &DasConfig, drafter: Box<dyn Drafter>) -> Self {
        #[allow(clippy::expect_used)]
        // audit: allow(panic-path) -- config validate() already parsed this policy string
        let budget_policy = BudgetPolicy::parse(&cfg.spec.budget_policy).expect("validated");
        let mut drafter = drafter;
        // Warm start: restore the snapshot and replay the WAL tail from a
        // READ-ONLY view first — a store this engine ends up refusing
        // (parameter drift, corruption) must come through untouched, repair
        // side effects included. Only once the drafter accepted the state
        // is the store opened for writing (which repairs torn tails /
        // discards subsumed logs — yielding exactly the records the view
        // reported, since both run the same scan). Persistence failures
        // NEVER take the engine down — they fall back to the historical
        // cold-start behavior (and disable the store rather than write
        // records on top of a snapshot that was not restored).
        let store = if cfg.spec.store_dir.is_empty() || !drafter.persistent() {
            None
        } else {
            match HistoryStore::peek(Path::new(&cfg.spec.store_dir)) {
                Ok(view) => {
                    let restored = match &view.snapshot {
                        Some(snap) => match drafter.load_state(snap) {
                            Ok(()) => true,
                            Err(e) => {
                                eprintln!(
                                    "das-store: warm start from '{}' skipped ({e}); \
                                     running cold without persistence",
                                    cfg.spec.store_dir
                                );
                                false
                            }
                        },
                        None => true, // fresh store: nothing to restore yet
                    };
                    if restored {
                        replay_wal(&mut *drafter, &view.wal);
                        match HistoryStore::open(Path::new(&cfg.spec.store_dir)) {
                            Ok(store) => Some(store),
                            Err(e) => {
                                eprintln!(
                                    "das-store: cannot open '{}' for writing ({e}); \
                                     continuing without persistence",
                                    cfg.spec.store_dir
                                );
                                None
                            }
                        }
                    } else {
                        None
                    }
                }
                Err(e) => {
                    eprintln!(
                        "das-store: cannot read '{}' ({e}); running without persistence",
                        cfg.spec.store_dir
                    );
                    None
                }
            }
        };
        RolloutEngine {
            drafter,
            length_policy: LengthPolicy::from_das(cfg),
            acceptance: HashMap::new(),
            budget_policy,
            budget_short: cfg.spec.budget_short,
            budget_medium: cfg.spec.budget_medium,
            budget_long: cfg.spec.budget_long,
            budget_cap: cfg.spec.budget_cap.max(1),
            max_batch: cfg.rollout.max_batch,
            max_new_tokens: cfg.rollout.max_new_tokens,
            temperature: cfg.rollout.temperature,
            next_request: 0,
            epoch: 0,
            seed: cfg.seed,
            draft_threads: cfg.spec.draft_threads,
            store,
            // Clamp BEFORE the narrowing cast: a usize that is a multiple
            // of 2^32 must not truncate to a zero divisor.
            snapshot_every: (cfg.spec.snapshot_every.min(Epoch::MAX as usize) as Epoch).max(1),
            last_roll_persisted: None,
            faults: Arc::new(FaultPlan::parse(&cfg.rollout.fault_plan).unwrap_or_else(|e| {
                // Config validation rejects bad plans before they get here;
                // a standalone engine built from a hand-rolled config just
                // runs without injection.
                eprintln!("das: invalid rollout.fault_plan ({e}); ignoring");
                FaultPlan::default()
            })),
            degraded: HashSet::new(),
            worker_index: 0,
            preempt_latch: None,
            resume_budget_boost: cfg.spec.resume_budget_boost.clamp(1.0, 8.0),
            pending_store_failures: 0,
        }
    }

    /// Tell the engine which pool slot it occupies, so `preempt worker=W`
    /// fault directives can address it.
    pub fn set_worker_index(&mut self, w: usize) {
        self.worker_index = w;
    }

    /// Install the coordinator's preemption latch for this engine's slot.
    pub fn set_preempt_latch(&mut self, latch: Arc<AtomicBool>) {
        self.preempt_latch = Some(latch);
    }

    pub fn set_temperature(&mut self, t: f64) {
        self.temperature = t;
    }

    /// Share a fault plan across engines: the supervising pool hands every
    /// worker incarnation the same `Arc` so one-shot injections fire once
    /// fleet-wide, not once per respawn.
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.faults = plan;
    }

    /// Advance the epoch (window maintenance in the drafter). With a store
    /// configured, the FIRST announcement of each epoch also persists: a
    /// full snapshot commit every `spec.snapshot_every` epochs (resetting
    /// the WAL it subsumes), a `RollEpoch` WAL record otherwise.
    pub fn roll_epoch(&mut self, epoch: Epoch) {
        self.epoch = epoch;
        self.drafter.roll_epoch(epoch);
        let Some(store) = self.store.as_mut() else {
            return;
        };
        if self.last_roll_persisted == Some(epoch) {
            return;
        }
        self.last_roll_persisted = Some(epoch);
        let result = if self.faults.store_fails(epoch) {
            Err(StoreError::Io("injected write failure (fault plan)".into()))
        } else if epoch % self.snapshot_every == 0 {
            let payload = self.drafter.save_state();
            store.commit_snapshot(&payload)
        } else {
            store.append(&WalRecord::RollEpoch(epoch))
        };
        if let Err(e) = result {
            eprintln!("das-store: persist failed ({e}); disabling persistence");
            self.store = None;
            self.pending_store_failures += 1;
        }
    }

    /// Size/latency gauges of the persistent store, if one is attached.
    pub fn store_status(&self) -> Option<StoreStatus> {
        self.store.as_ref().map(|s| s.status())
    }

    pub fn epoch(&self) -> Epoch {
        self.epoch
    }

    /// Predicted device cost of a job: samples × expected generation length
    /// under the length policy's history. Coordinators use this to shard
    /// jobs longest-predicted-first (LPT) instead of round-robin.
    pub fn predict_job_cost(&self, job: &GenJob) -> f64 {
        self.length_policy.job_cost(job.problem, job.samples)
    }

    /// Reader threads for one round's draft phase: `spec.draft_threads`,
    /// with 0 = auto (available parallelism, capped at 8 — draft batches
    /// rarely scale past that), never more than one thread per request.
    fn draft_thread_count(&self, active: usize) -> usize {
        let configured = if self.draft_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        } else {
            self.draft_threads
        };
        configured.min(active)
    }

    fn class_budget(&self, class: LengthClass) -> usize {
        match class {
            LengthClass::Short => self.budget_short,
            LengthClass::Medium => self.budget_medium,
            LengthClass::Long => self.budget_long,
        }
    }

    /// Decide per-request draft budgets for this round.
    fn budgets(
        &self,
        active: &[RolloutRequest],
        model: &dyn Fn() -> crate::cost::LatencyModel,
    ) -> Vec<usize> {
        match self.budget_policy {
            BudgetPolicy::Uniform => vec![self.budget_medium.max(1); active.len()],
            BudgetPolicy::Unlimited => vec![self.budget_cap; active.len()],
            BudgetPolicy::LengthAware => active
                .iter()
                .map(|r| {
                    let class =
                        self.length_policy
                            .runtime_class(r.problem, r.gen_len(), r.init_class);
                    self.class_budget(class).min(self.budget_cap)
                })
                .collect(),
            BudgetPolicy::Optimal => {
                // Eq. 9: solve for N_fwd over the active batch, then spread
                // each request's total budget p* across its expected rounds.
                let reqs: Vec<BudgetRequest> = active
                    .iter()
                    .map(|r| {
                        let class = self.length_policy.runtime_class(
                            r.problem,
                            r.gen_len(),
                            r.init_class,
                        );
                        let l = self
                            .length_policy
                            .expected_remaining(r.problem, r.gen_len(), class);
                        let accept = self
                            .acceptance
                            .get(&r.problem)
                            .map(|e| e.params())
                            .unwrap_or_default();
                        BudgetRequest { length: l, accept }
                    })
                    .collect();
                let sol = solve_budget(&reqs, &model());
                sol.budgets
                    .iter()
                    .map(|&p| {
                        if !p.is_finite() || sol.n_fwd <= 0.0 {
                            self.budget_medium
                        } else {
                            ((p / sol.n_fwd).ceil() as usize).min(self.budget_cap)
                        }
                    })
                    .collect()
            }
        }
    }

    /// Generate rollouts for a batch of jobs. `step` tags provenance; the
    /// engine's RNG forks deterministically from `(seed, step, request id)`.
    pub fn generate_step<M: TargetModel>(
        &mut self,
        model: &mut M,
        jobs: &[GenJob],
        step: u32,
    ) -> StepReport {
        let mut batcher = Batcher::new(self.max_batch);
        let mut step_rng = Rng::seed_from_u64(
            self.seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        for job in jobs {
            for s in 0..job.samples {
                let id = self.next_request;
                self.next_request += 1;
                let rng = step_rng.fork(id ^ ((s as u64) << 40));
                let init_class = self.length_policy.init_class(job.problem);
                batcher.submit(RolloutRequest::new(
                    id,
                    job.problem,
                    job.prompt.clone(),
                    rng,
                    init_class,
                ));
            }
        }
        self.run_decode(model, batcher, step, 1.0)
    }

    /// Resume checkpointed requests migrated from another worker. Each one
    /// continues from its freeze point bit-identically: the RNG stream is
    /// restored verbatim (never re-forked — worker seeds differ), the
    /// per-request drafter scope is rebuilt by replaying the origin's
    /// commit-chunk boundaries, and degraded requests stay degraded. Draft
    /// budgets are escalated by `spec.resume_budget_boost`: a migrated
    /// request is a known straggler on an otherwise-idle worker, where
    /// deeper speculation is nearly free — and at temperature 0,
    /// losslessness makes the deeper budget a pure latency effect.
    pub fn resume_step<M: TargetModel>(
        &mut self,
        model: &mut M,
        checkpoints: &[RequestCheckpoint],
        step: u32,
    ) -> StepReport {
        let mut batcher = Batcher::new(self.max_batch);
        for ck in checkpoints {
            let id = self.next_request;
            self.next_request += 1;
            // Rebuild the drafter's request-local scope exactly: absorb the
            // origin's committed runs chunk-by-chunk (chunks never
            // cross-connect inside the request-local index, so boundaries
            // matter, not just the token stream).
            let mut off = 0usize;
            for &c in &ck.commit_chunks {
                let end = off + c as usize;
                self.drafter.observe_partial(id, ck.problem, &ck.generated[off..end]);
                off = end;
            }
            if ck.degraded {
                // A poisoned drafter must stay poisoned across migration.
                self.degraded.insert(id);
            }
            batcher.submit(RolloutRequest::from_checkpoint(id, ck));
        }
        let boost = self.resume_budget_boost;
        self.run_decode(model, batcher, step, boost)
    }

    /// The decode loop shared by fresh steps and resumed checkpoints.
    /// `boost` > 1 escalates every per-round draft budget (clamped to
    /// `spec.budget_cap`); 1.0 is the plain path.
    fn run_decode<M: TargetModel>(
        &mut self,
        model: &mut M,
        mut batcher: Batcher,
        step: u32,
        boost: f64,
    ) -> StepReport {
        // audit: allow(wall-clock-determinism) -- gen_time gauge only; decode never reads it
        let wall_start = Instant::now();
        // Chaos seam: a `kill-draftsvc step=S` directive murders the draft
        // daemon before this step drafts anything, so the whole step
        // exercises the timeout → retry → degrade ladder.
        if self.faults.should_kill_draftsvc(step) {
            self.drafter.kill_remote();
        }
        model.reset_clock();
        let fwd0 = model.forward_passes();
        let mut metrics = StepMetrics::default();
        if boost > 1.0 && batcher.pending_len() > 0 {
            metrics.resume_budget_boost = boost;
        }
        let eos = model.eos();
        let latency = model.latency_model();
        let mut rollouts = Vec::new();
        let mut accept_obs = Vec::new();
        let mut checkpoints: Vec<RequestCheckpoint> = Vec::new();
        // Absorb cursor into `rollouts`: finished trajectories become WAL
        // records immediately (in `finish_request`) but enter the drafter's
        // in-memory history lazily, so the concurrent path can overlap
        // absorption with snapshot drafting.
        let mut absorbed = 0usize;

        loop {
            let done = batcher.recycle();
            for req in &done {
                self.finish_request(req, step, &mut rollouts, &mut metrics, &mut accept_obs);
            }
            batcher.archive(done);
            if batcher.effective_batch() == 0 {
                break;
            }
            // Preemption seam: verification-round boundaries are the only
            // points where every in-flight request is self-consistent
            // (tokens committed, drafter scope absorbed, RNG between
            // draws), so freezing here makes the checkpoint sufficient for
            // a bit-identical resume elsewhere. Guard on rounds > 0 FIRST:
            // `should_preempt` is one-shot, and consuming it before any
            // work ran would freeze an empty step.
            let preempted = metrics.rounds > 0
                && (self.faults.should_preempt(self.worker_index, step)
                    || self
                        .preempt_latch
                        .as_ref()
                        // One-shot consume of the supervisor's preempt latch.
                        // audit: allow(atomic-ordering) -- Relaxed swap; publishes no data
                        .is_some_and(|l| l.swap(false, Ordering::Relaxed)));
            if preempted {
                for req in batcher.take_unfinished() {
                    let degraded = self.degraded.remove(&req.id);
                    // The request's scope leaves this drafter; the
                    // destination rebuilds it from the checkpoint's
                    // commit-chunk boundaries.
                    self.drafter.end_request(req.id);
                    checkpoints.push(req.checkpoint(degraded));
                }
                metrics.preemptions += 1;
                break;
            }
            metrics.eff_batch.push(batcher.effective_batch() as u32);

            // 1. Budgets. Resumed stragglers get escalated depth: the boost
            // multiplies every per-round budget (clamped to budget_cap), and
            // at temperature 0 losslessness guarantees the deeper draft is a
            // pure latency effect — outputs cannot change.
            let budgets = {
                let active = batcher.active();
                let mut b = self.budgets(active, &|| latency);
                if boost > 1.0 {
                    for budget in &mut b {
                        *budget = escalate(*budget, boost, self.budget_cap);
                    }
                }
                b
            };

            // 2. Drafts (speculation overhead measured in wall time). The
            // decode context is a zero-copy slice of each request's token
            // buffer — no per-round materialization. With more than one
            // draft thread and a snapshot-capable drafter, drafting runs
            // lock-free on worker threads against the last published
            // snapshot while this (writer) thread absorbs pending rollouts;
            // otherwise the serial path drafts against the live structures.
            let threads = self.draft_thread_count(batcher.effective_batch());
            let snap = if threads > 1 { self.drafter.snapshot() } else { None };
            if snap.is_none() {
                // Serial visibility: every rollout finished so far is
                // indexed before this round's drafts are computed.
                absorb_pending(&mut *self.drafter, &rollouts, &mut absorbed);
            }
            // audit: allow(wall-clock-determinism) -- draft-overhead gauge only, never replayed
            let draft_start = Instant::now();
            let mut drafts: Vec<Vec<TokenId>> = Vec::with_capacity(budgets.len());
            if let Some(snap) = snap {
                // Snapshots may trail the live history by the rollouts still
                // pending absorption (one round), never by epochs unless the
                // drafter skipped a publish — surfaced as a staleness gauge.
                metrics.draft_snapshot_lag_epochs = metrics
                    .draft_snapshot_lag_epochs
                    .max(u64::from(self.epoch.saturating_sub(snap.epoch())));
                let specs: Vec<(RequestId, ProblemId, usize, bool)> = {
                    let active = batcher.active();
                    active
                        .iter()
                        .zip(&budgets)
                        .map(|(req, &budget)| {
                            // Never draft past the generation cap (leave
                            // room for the guaranteed extra token).
                            let room =
                                self.max_new_tokens.saturating_sub(req.gen_len() + 1);
                            (
                                req.id,
                                req.problem,
                                budget.min(room),
                                self.degraded.contains(&req.id),
                            )
                        })
                        .collect()
                };
                let faults = Arc::clone(&self.faults);
                let chunk = specs.len().div_ceil(threads);
                let mut results: Vec<(Vec<TokenId>, DraftOutcome, bool)> =
                    Vec::with_capacity(specs.len());
                {
                    let active = batcher.active();
                    std::thread::scope(|s| {
                        let mut handles = Vec::with_capacity(threads);
                        for (ci, chunk_specs) in specs.chunks(chunk).enumerate() {
                            let lo = ci * chunk;
                            let snap = &snap;
                            let faults = &faults;
                            let n = chunk_specs.len();
                            let handle = s.spawn(move || {
                                // Degradation ladder, rung 1b: this panic
                                // fires OUTSIDE the per-request
                                // catch_unwind — the host thread itself
                                // dies, exercising the join-side recovery
                                // below (a real reader host can die in the
                                // slicing/setup code around the guarded
                                // draft call).
                                if faults.should_poison_host(step) {
                                    // audit: allow(panic-path) -- this panic IS the injected fault
                                    panic!("fault plan: poisoned draft host at step {step}");
                                }
                                chunk_specs
                                    .iter()
                                    .enumerate()
                                    .map(|(j, &(id, problem, b, degraded))| {
                                        if b == 0 || degraded {
                                            return (
                                                Vec::new(),
                                                DraftOutcome::Skipped,
                                                false,
                                            );
                                        }
                                        // Degradation ladder, rung 1: a
                                        // panicking draft must not unwind
                                        // out of its worker. The request
                                        // falls back to plain decoding —
                                        // losslessness makes that a pure
                                        // slowdown, never an output change.
                                        let context = active[lo + j].context();
                                        let attempt =
                                            catch_unwind(AssertUnwindSafe(|| {
                                                if faults.should_poison_draft(step) {
                                                    // audit: allow(panic-path) -- injected fault
                                                    panic!(
                                                        "fault plan: poisoned draft at step {step}"
                                                    );
                                                }
                                                snap.draft(id, problem, context, b)
                                            }));
                                        match attempt {
                                            Ok((d, outcome)) => (d.tokens, outcome, false),
                                            Err(_) => {
                                                (Vec::new(), DraftOutcome::Skipped, true)
                                            }
                                        }
                                    })
                                    .collect::<Vec<_>>()
                            });
                            handles.push((handle, n));
                        }
                        // Writer overlap: index rollouts finished in earlier
                        // rounds while the readers draft off the snapshot.
                        absorb_pending(&mut *self.drafter, &rollouts, &mut absorbed);
                        for (h, n) in handles {
                            match h.join() {
                                Ok(part) => results.extend(part),
                                // A reader host died outside the per-request
                                // catch_unwind. Don't abort the step: every
                                // request in the dead host's chunk degrades
                                // to plain decoding (empty draft, counted
                                // below), and the round continues on
                                // whatever the surviving hosts produced.
                                Err(_) => results.extend(
                                    std::iter::repeat_with(|| {
                                        (Vec::new(), DraftOutcome::Skipped, true)
                                    })
                                    .take(n),
                                ),
                            }
                        }
                    });
                }
                // Fold the round's outcomes back into the drafter's
                // hit/miss diagnostics (snapshots cannot bump them) and
                // mark panicked requests degraded.
                let (mut local_hits, mut shard_hits, mut misses) = (0u64, 0u64, 0u64);
                for (i, (tokens, outcome, panicked)) in results.into_iter().enumerate() {
                    if panicked {
                        self.degraded.insert(specs[i].0);
                        metrics.degraded_requests += 1;
                    }
                    match outcome {
                        DraftOutcome::Local => local_hits += 1,
                        DraftOutcome::Shard => shard_hits += 1,
                        DraftOutcome::Miss => misses += 1,
                        DraftOutcome::Skipped => {}
                    }
                    drafts.push(tokens);
                }
                self.drafter.apply_draft_outcomes(local_hits, shard_hits, misses);
            } else {
                let active = batcher.active();
                for (req, &budget) in active.iter().zip(&budgets) {
                    // Never draft past the generation cap (leave room for
                    // the guaranteed extra token).
                    let room = self.max_new_tokens.saturating_sub(req.gen_len() + 1);
                    let b = budget.min(room);
                    let d = if b == 0 || self.degraded.contains(&req.id) {
                        Vec::new()
                    } else {
                        // Degradation ladder, rung 1: a panicking drafter
                        // must not unwind out of the decode loop. The
                        // request falls back to plain decoding (an empty
                        // draft every round) — losslessness makes that a
                        // pure slowdown, never an output change.
                        let drafter = &mut self.drafter;
                        let faults = &self.faults;
                        let attempt = catch_unwind(AssertUnwindSafe(|| {
                            if faults.should_poison_draft(step) {
                                // audit: allow(panic-path) -- this panic IS the injected fault
                                panic!("fault plan: poisoned draft at step {step}");
                            }
                            drafter.draft(req.id, req.problem, req.context(), b).tokens
                        }));
                        match attempt {
                            Ok(tokens) => tokens,
                            Err(_) => {
                                self.degraded.insert(req.id);
                                metrics.degraded_requests += 1;
                                Vec::new()
                            }
                        }
                    };
                    drafts.push(d);
                }
            }
            metrics.draft_time += draft_start.elapsed().as_secs_f64();

            // 3. One batched verify forward.
            let inputs: Vec<StepInput> = {
                let active = batcher.active();
                active
                    .iter()
                    .enumerate()
                    .map(|(i, req)| StepInput {
                        request: req.id,
                        problem: req.problem,
                        context: req.context(),
                        prompt_len: req.prompt_len(),
                        draft: &drafts[i],
                    })
                    .collect()
            };
            let outputs = model.forward(&inputs, self.temperature);
            drop(inputs);
            metrics.rounds += 1;

            // 4. Verify + commit.
            let greedy = self.temperature <= 0.0;
            let active = batcher.active_mut();
            for (i, req) in active.iter_mut().enumerate() {
                let draft = &drafts[i];
                let dists = &outputs[i];
                metrics.tokens_processed += (draft.len() + 1) as u64;
                let outcome = if greedy {
                    verify_greedy(draft, dists)
                } else {
                    verify_sampling(draft, dists, &mut req.rng)
                };
                metrics.proposed += draft.len() as u64;
                metrics.accepted += outcome.accepted as u64;
                req.rounds += 1;
                req.proposed += draft.len() as u64;
                req.accepted += outcome.accepted as u64;
                if !draft.is_empty() {
                    self.acceptance
                        .entry(req.problem)
                        .or_default()
                        .observe(draft.len(), outcome.accepted);
                }
                let committed = req.commit(&outcome.tokens, eos, self.max_new_tokens);
                metrics.generated += committed as u64;
                let gl = req.gen_len();
                let new_tokens: Vec<TokenId> = req.generated()[gl - committed..].to_vec();
                self.drafter.observe_partial(req.id, req.problem, &new_tokens);
            }
        }

        // The final recycle's rollouts are still pending when the loop
        // breaks — index them now so cross-step drafter state is identical
        // whether this step drafted serially or concurrently.
        absorb_pending(&mut *self.drafter, &rollouts, &mut absorbed);

        metrics.gen_time = model.elapsed() + latency.c_step;
        metrics.wall_time = wall_start.elapsed().as_secs_f64();
        // Index-size gauges: how much memory the drafter's history costs
        // (nodes vs uncompressed-equivalent positions makes the
        // path-compression win observable). Cheap per step: every count is
        // maintained incrementally by the arena core and stamped onto
        // publications, so no shard walk happens here.
        let idx = self.drafter.index_stats();
        metrics.index_nodes = idx.nodes as u64;
        metrics.index_token_positions = idx.token_positions as u64;
        metrics.index_bytes = idx.heap_bytes as u64;
        metrics.pool_segments = idx.pool_segments as u64;
        metrics.pool_tokens = idx.pool_tokens as u64;
        metrics.pool_bytes = idx.pool_bytes as u64;
        metrics.index_link_rebuilds = idx.link_rebuilds;
        metrics.index_snapshot_publishes = idx.snapshot_publishes;
        if let Some(store) = &self.store {
            let st = store.status();
            metrics.store_snapshot_bytes = st.snapshot_bytes;
            metrics.store_wal_records = st.wal_records;
            metrics.store_wal_bytes = st.wal_bytes;
            metrics.store_persist_s = st.last_persist_secs;
        }
        // Surface store failures exactly once, including those from epoch
        // rolls between steps.
        metrics.store_failures = std::mem::take(&mut self.pending_store_failures);
        // Remote draft service counters (drained per step; zero for local
        // substrates, where `remote_stats` returns None).
        if let Some(rs) = self.drafter.remote_stats() {
            metrics.remote_round_trips = rs.round_trips;
            metrics.remote_contexts = rs.contexts;
            metrics.remote_timeouts = rs.timeouts;
            metrics.remote_reconnects = rs.reconnects;
            metrics.remote_degraded = rs.degraded;
            metrics.remote_rpc_p50_s = rs.rpc_p50_s;
            metrics.remote_rpc_p99_s = rs.rpc_p99_s;
        }
        // All passes this engine saw belong to this step's rounds.
        debug_assert_eq!(model.forward_passes() - fwd0, metrics.rounds);
        StepReport {
            rollouts,
            metrics,
            accept_obs,
            checkpoints,
        }
    }

    fn finish_request(
        &mut self,
        req: &RolloutRequest,
        step: u32,
        rollouts: &mut Vec<Rollout>,
        metrics: &mut StepMetrics,
        accept_obs: &mut Vec<(ProblemId, u64, u64)>,
    ) {
        metrics.completed += 1;
        self.degraded.remove(&req.id);
        self.drafter.end_request(req.id);
        self.length_policy.observe(req.problem, req.gen_len());
        // Both halves of the LPT cost key: final length above, speculation
        // outcome here (well-speculating problems cost fewer forwards per
        // token). Also exported so the data-parallel coordinator's
        // predictor sees the same signal.
        self.length_policy
            .observe_acceptance(req.problem, req.rounds as u64, req.accepted);
        accept_obs.push((req.problem, req.rounds as u64, req.accepted));
        let rollout = Rollout {
            problem: req.problem,
            epoch: self.epoch,
            step,
            tokens: req.generated().to_vec(),
            reward: 0.0,
        };
        // Write-ahead: the rollout is durable BEFORE it enters the
        // in-memory history, so a crash replays exactly what was indexed.
        if let Some(store) = &mut self.store {
            let rec = WalRecord::Absorb {
                problem: rollout.problem,
                epoch: rollout.epoch,
                tokens: rollout.tokens.clone(),
            };
            // Degradation ladder, rung 2: mid-run IO errors (real or
            // injected) disable persistence and count a failure; the run
            // itself continues on the historical no-store behavior.
            let result = if self.faults.store_fails(self.epoch) {
                Err(StoreError::Io("injected write failure (fault plan)".into()))
            } else {
                store.append(&rec)
            };
            if let Err(e) = result {
                eprintln!("das-store: WAL append failed ({e}); disabling persistence");
                self.store = None;
                self.pending_store_failures += 1;
            }
        }
        // Online drafter refresh: newly finished trajectories become draft
        // material for still-running stragglers — exactly the idle-slack
        // exploitation the paper describes. The actual indexing is deferred
        // to the step loop's absorb cursor (`absorb_pending`) so the
        // concurrent path can overlap it with snapshot drafting.
        rollouts.push(rollout);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[allow(unused_imports)]
    use crate::drafter::{NoneDrafter, SuffixDrafter};
    use crate::model::sim::{SimModel, SimModelConfig};

    fn cfg(temp: f64, drafter: &str, policy: &str) -> DasConfig {
        let mut c = DasConfig::default();
        c.model.vocab_size = 64;
        c.workload.n_problems = 6;
        c.workload.len_mu = 3.2;
        c.workload.len_sigma = 0.4;
        c.rollout.max_new_tokens = 128;
        c.rollout.max_batch = 4;
        c.rollout.temperature = temp;
        c.spec.drafter = drafter.into();
        c.spec.budget_policy = policy.into();
        c
    }

    fn sim(c: &DasConfig) -> SimModel {
        SimModel::new(SimModelConfig::from_das(c))
    }

    fn jobs(n: usize, samples: usize) -> Vec<GenJob> {
        (0..n)
            .map(|p| GenJob {
                problem: p as u32,
                prompt: vec![p as u32 + 1, 7, 9],
                samples,
            })
            .collect()
    }

    fn engine(c: &DasConfig) -> RolloutEngine {
        RolloutEngine::new(c, crate::drafter::from_config(c))
    }

    #[test]
    fn step_metrics_carry_index_gauges() {
        let c = cfg(0.6, "das", "length_aware");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let rep = e.generate_step(&mut m, &jobs(6, 2), 0);
        // After a step the drafter has indexed its rollouts: the gauges
        // must be populated and the compressed node count can never exceed
        // the uncompressed-equivalent position count.
        assert!(rep.metrics.index_nodes > 0, "das drafter indexed something");
        assert!(rep.metrics.index_token_positions >= rep.metrics.index_nodes);
        assert!(rep.metrics.index_bytes > 0);
        assert!(rep.metrics.pool_tokens > 0, "rollout content interned in the pool");
        // The none drafter reports all-zero gauges.
        let c = cfg(0.6, "none", "length_aware");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let rep = e.generate_step(&mut m, &jobs(2, 1), 0);
        assert_eq!(rep.metrics.index_nodes, 0);
        assert_eq!(rep.metrics.pool_tokens, 0);
    }

    #[test]
    fn baseline_generates_all_rollouts() {
        let c = cfg(0.6, "none", "length_aware");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let rep = e.generate_step(&mut m, &jobs(6, 2), 0);
        assert_eq!(rep.rollouts.len(), 12);
        assert_eq!(rep.metrics.completed, 12);
        assert!(rep.metrics.rounds > 0);
        assert_eq!(rep.metrics.proposed, 0, "none drafter never proposes");
        // Every rollout ends with EOS or hit the cap.
        for r in &rep.rollouts {
            assert!(
                *r.tokens.last().unwrap() == m.eos() || r.tokens.len() == 128,
                "rollout must terminate properly"
            );
        }
    }

    #[test]
    fn effective_batch_collapses() {
        // Fig. 1 mechanism: the eff-batch trace is non-increasing once the
        // pending queue drains, ending at 1.
        let c = cfg(0.6, "none", "length_aware");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let rep = e.generate_step(&mut m, &jobs(6, 2), 0);
        let trace = &rep.metrics.eff_batch;
        assert_eq!(trace[0] as usize, 4); // starts at max_batch
        assert_eq!(*trace.last().unwrap(), 1); // single straggler at the end
    }

    #[test]
    fn greedy_spec_equals_greedy_baseline_bitwise() {
        // THE losslessness anchor: at T=0, DAS output == baseline output
        // exactly, token for token, for every rollout.
        let c_base = cfg(0.0, "none", "length_aware");
        let c_das = cfg(0.0, "das", "length_aware");
        let mut m1 = sim(&c_base);
        let mut m2 = sim(&c_das);
        let mut e1 = engine(&c_base);
        let mut e2 = engine(&c_das);
        for step in 0..3 {
            let r1 = e1.generate_step(&mut m1, &jobs(6, 2), step);
            let r2 = e2.generate_step(&mut m2, &jobs(6, 2), step);
            let key = |r: &Rollout| (r.problem, r.tokens.clone());
            let mut a: Vec<_> = r1.rollouts.iter().map(key).collect();
            let mut b: Vec<_> = r2.rollouts.iter().map(key).collect();
            a.sort();
            b.sort();
            assert_eq!(a, b, "greedy outputs must be bit-identical at step {step}");
            // And DAS must actually be speculating by step 1+.
            if step > 0 {
                assert!(r2.metrics.accepted > 0, "DAS accepted nothing");
            }
        }
    }

    #[test]
    fn das_reduces_gen_time_after_warmup() {
        let c_base = cfg(0.6, "none", "length_aware");
        let c_das = cfg(0.6, "das", "length_aware");
        let mut m1 = sim(&c_base);
        let mut m2 = sim(&c_das);
        let mut e1 = engine(&c_base);
        let mut e2 = engine(&c_das);
        let mut base_t = 0.0;
        let mut das_t = 0.0;
        for step in 0..4 {
            let r1 = e1.generate_step(&mut m1, &jobs(6, 4), step);
            let r2 = e2.generate_step(&mut m2, &jobs(6, 4), step);
            if step > 0 {
                base_t += r1.metrics.gen_time;
                das_t += r2.metrics.gen_time;
            }
            // Simulate a policy update (both models drift identically).
            m1.policy_update(1.0);
            m2.policy_update(1.0);
            e1.roll_epoch(step + 1);
            e2.roll_epoch(step + 1);
        }
        assert!(
            das_t < base_t,
            "DAS should cut generation time: das={das_t:.3}s base={base_t:.3}s"
        );
    }

    #[test]
    fn rollout_lengths_respect_cap() {
        let c = cfg(0.9, "das", "unlimited");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let rep = e.generate_step(&mut m, &jobs(6, 2), 0);
        for r in &rep.rollouts {
            assert!(r.tokens.len() <= 128);
        }
    }

    #[test]
    fn optimal_policy_runs() {
        let c = cfg(0.6, "das", "optimal");
        let mut m = sim(&c);
        let mut e = engine(&c);
        for step in 0..2 {
            let rep = e.generate_step(&mut m, &jobs(6, 2), step);
            assert_eq!(rep.metrics.completed, 12);
        }
    }

    #[test]
    fn metrics_accounting_consistent() {
        let c = cfg(0.6, "das", "uniform");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let rep = e.generate_step(&mut m, &jobs(4, 2), 0);
        let mm = &rep.metrics;
        assert!(mm.accepted <= mm.proposed);
        // Generated tokens ≥ rounds is NOT guaranteed per-request, but
        // tokens_processed ≥ generated ≥ completed always holds.
        assert!(mm.tokens_processed >= mm.generated);
        assert!(mm.generated >= mm.completed);
        let total_tokens: u64 = rep.rollouts.iter().map(|r| r.tokens.len() as u64).sum();
        assert_eq!(total_tokens, mm.generated);
        assert_eq!(mm.eff_batch.len() as u64, mm.rounds);
    }

    #[test]
    fn job_cost_prediction_follows_observed_lengths() {
        let c = cfg(0.6, "none", "length_aware");
        let mut e = engine(&c);
        // Cold start: all problems predict the same cost.
        let js = jobs(2, 2);
        assert_eq!(e.predict_job_cost(&js[0]), e.predict_job_cost(&js[1]));
        // Samples scale the prediction linearly.
        let mut big = js[0].clone();
        big.samples = 4;
        assert!((e.predict_job_cost(&big) - 2.0 * e.predict_job_cost(&js[0])).abs() < 1e-9);
        // After observing real lengths the prediction must differentiate:
        // problem 0 runs long (120 >= t_long for the 128-token cap),
        // problem 1 short — the long problem must predict strictly costlier.
        for _ in 0..3 {
            e.length_policy.observe(0, 120);
            e.length_policy.observe(1, 3);
        }
        assert!(
            e.predict_job_cost(&js[0]) > e.predict_job_cost(&js[1]),
            "LPT key must follow observed lengths: long={} short={}",
            e.predict_job_cost(&js[0]),
            e.predict_job_cost(&js[1])
        );
    }

    #[test]
    fn greedy_outputs_invariant_across_draft_sources() {
        // The DraftSource seam: whichever substrate backs speculation
        // (fused windowed trie, Ukkonen tree, rebuild-per-insert suffix
        // array — or no speculation at all), greedy outputs are
        // bit-identical. The substrate is a pure performance knob.
        let reference = {
            let c = cfg(0.0, "none", "length_aware");
            let mut m = sim(&c);
            let mut e = engine(&c);
            let rep = e.generate_step(&mut m, &jobs(4, 2), 0);
            let mut k: Vec<_> = rep
                .rollouts
                .iter()
                .map(|r| (r.problem, r.tokens.clone()))
                .collect();
            k.sort();
            k
        };
        for substrate in ["window", "tree", "array"] {
            let mut c = cfg(0.0, "das", "length_aware");
            c.spec.substrate = substrate.into();
            let mut m = sim(&c);
            let mut e = engine(&c);
            let rep = e.generate_step(&mut m, &jobs(4, 2), 0);
            let mut k: Vec<_> = rep
                .rollouts
                .iter()
                .map(|r| (r.problem, r.tokens.clone()))
                .collect();
            k.sort();
            assert_eq!(k, reference, "substrate '{substrate}' broke losslessness");
        }
    }

    #[test]
    fn acceptance_feeds_lpt_cost_key() {
        // After a speculating step, finished requests' acceptance outcomes
        // must be exported AND folded into the engine's own job-cost
        // prediction (well-speculating problems predict cheaper than their
        // raw length history alone).
        let c = cfg(0.0, "das", "uniform");
        let mut m = sim(&c);
        for _ in 0..60 {
            m.policy_update(1.0); // sharpen so greedy paths repeat
        }
        let mut e = engine(&c);
        // More samples than batch slots: a problem's stragglers start after
        // its first wave finished and seeded the shard, guaranteeing
        // within-step acceptance (same mechanism as
        // `suffix_drafter_learns_within_step`).
        let rep = e.generate_step(&mut m, &jobs(2, 6), 0);
        assert_eq!(rep.accept_obs.len(), 12, "one record per finished request");
        assert!(rep.accept_obs.iter().all(|&(_, rounds, _)| rounds > 0));
        let total_acc: u64 = rep.accept_obs.iter().map(|&(_, _, a)| a).sum();
        assert_eq!(total_acc, rep.metrics.accepted, "obs must account for all acceptance");
        let (p, _, _) = *rep
            .accept_obs
            .iter()
            .find(|&&(_, _, a)| a > 0)
            .expect("sharpened greedy run must accept for some problem");
        let apr = e.length_policy.accepted_per_round(p);
        assert!(apr > 0.0, "engine must feed acceptance into its length policy");
        // The prediction must be EXACTLY the length-based expectation
        // discounted by the acceptance rate — if job_cost dropped the
        // /(1 + apr) fold, this fails (expected_total is the undiscounted
        // half of the key).
        let predicted = e.predict_job_cost(&GenJob {
            problem: p,
            prompt: vec![1],
            samples: 1,
        });
        let undiscounted = e.length_policy.expected_total(p);
        assert!(
            (predicted - undiscounted / (1.0 + apr)).abs() < 1e-9,
            "LPT key must fold acceptance: predicted={predicted} undiscounted={undiscounted} apr={apr}"
        );
        assert!(predicted < undiscounted, "discount must bite for an accepting problem");
    }

    fn sorted_rollouts(rep: &StepReport) -> Vec<(u32, Vec<u32>)> {
        let mut k: Vec<_> = rep
            .rollouts
            .iter()
            .map(|r| (r.problem, r.tokens.clone()))
            .collect();
        k.sort();
        k
    }

    #[test]
    fn two_phase_warm_start_matches_uninterrupted_run() {
        // THE store acceptance test: train → kill → resume from the store
        // must (a) report nonzero restored index_token_positions on its
        // first step and (b) produce rollouts AND speculation outcomes
        // identical to a control run that was never killed.
        let dir = crate::store::test_dir("engine-two-phase");
        let mut c = cfg(0.0, "das", "uniform");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        c.spec.snapshot_every = 2;
        let mut c_ctrl = c.clone();
        c_ctrl.spec.store_dir = String::new();
        // Control: five uninterrupted steps.
        let mut control = Vec::new();
        {
            let mut m = sim(&c_ctrl);
            let mut e = engine(&c_ctrl);
            for step in 0..5 {
                e.roll_epoch(step);
                let rep = e.generate_step(&mut m, &jobs(4, 2), step);
                control.push((sorted_rollouts(&rep), rep.metrics.accepted));
                m.policy_update(1.0);
            }
        }
        // Phase 1: three steps with the store, then crash (drop mid-epoch:
        // the last step's rollouts live only in the WAL, not a snapshot).
        {
            let mut m = sim(&c);
            let mut e = engine(&c);
            for step in 0..3 {
                e.roll_epoch(step);
                let rep = e.generate_step(&mut m, &jobs(4, 2), step);
                assert_eq!(sorted_rollouts(&rep), control[step as usize].0, "phase-1 step {step}");
                assert!(
                    rep.metrics.store_snapshot_bytes > 0,
                    "snapshot gauge populated (epoch-0 commit)"
                );
                if step == 2 {
                    assert!(rep.metrics.store_wal_records > 0, "tail rollouts in the WAL");
                }
                m.policy_update(1.0);
            }
        }
        // Phase 2: fresh process — same config, model rebuilt and advanced
        // by the same number of learner updates; engine warm-starts.
        let mut m = sim(&c);
        for _ in 0..3 {
            m.policy_update(1.0);
        }
        let mut e = engine(&c);
        for step in 3..5u32 {
            e.roll_epoch(step);
            let rep = e.generate_step(&mut m, &jobs(4, 2), step);
            if step == 3 {
                assert!(
                    rep.metrics.index_token_positions > 0,
                    "first resumed step must report restored history"
                );
            }
            assert_eq!(sorted_rollouts(&rep), control[step as usize].0, "resumed step {step}");
            assert_eq!(
                rep.metrics.accepted, control[step as usize].1,
                "resumed drafts must match the never-killed control at step {step}"
            );
            m.policy_update(1.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stateless_drafters_never_touch_the_store() {
        // persistent() gates the machinery: a "none" drafter with a
        // store_dir configured must not even create the directory.
        let dir = crate::store::test_dir("engine-none-store");
        let mut c = cfg(0.6, "none", "length_aware");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        let mut m = sim(&c);
        let mut e = engine(&c);
        e.roll_epoch(0);
        let rep = e.generate_step(&mut m, &jobs(2, 1), 0);
        assert_eq!(rep.metrics.store_snapshot_bytes, 0);
        assert_eq!(rep.metrics.store_wal_records, 0);
        assert!(!dir.exists(), "no store files for stateless drafters");
    }

    #[test]
    fn config_drift_falls_back_to_cold_start() {
        // A snapshot taken under window=16 resumed under window=4: the
        // engine must refuse the warm start (Mismatch), run cold, and
        // disable persistence rather than corrupt the store.
        let dir = crate::store::test_dir("engine-drift");
        let mut c = cfg(0.0, "das", "uniform");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        {
            let mut m = sim(&c);
            let mut e = engine(&c);
            e.roll_epoch(0);
            e.generate_step(&mut m, &jobs(2, 2), 0);
        }
        let before = std::fs::read(dir.join("wal.das")).unwrap();
        let mut c2 = c.clone();
        c2.spec.window = 4;
        let mut m = sim(&c2);
        let mut e = engine(&c2);
        e.roll_epoch(1);
        let rep = e.generate_step(&mut m, &jobs(2, 2), 1);
        assert_eq!(rep.metrics.completed, 4, "cold run proceeds normally");
        assert_eq!(rep.metrics.store_wal_records, 0, "persistence disabled");
        let after = std::fs::read(dir.join("wal.das")).unwrap();
        assert_eq!(before, after, "refused warm start never writes the store");
        // Forensics path: even a DAMAGED log (torn tail — the kind the
        // writing open would repair in place) must survive a refused warm
        // start byte-for-byte, because the engine peeks read-only before
        // deciding.
        let mut torn = before.clone();
        torn.truncate(torn.len() - 3);
        std::fs::write(dir.join("wal.das"), &torn).unwrap();
        let mut e = engine(&c2);
        e.roll_epoch(1);
        assert!(e.store_status().is_none(), "still refused");
        assert_eq!(
            std::fs::read(dir.join("wal.das")).unwrap(),
            torn,
            "refused warm start leaves even damaged stores untouched"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn poisoned_drafter_degrades_to_plain_decoding() {
        // Degradation ladder rung 1: a drafter panic at T=0 must not change
        // a single output token — the poisoned request just stops
        // speculating, and the recovery is visible in the gauge.
        let c_ctrl = cfg(0.0, "das", "uniform");
        let mut c_chaos = c_ctrl.clone();
        c_chaos.rollout.fault_plan = "poison-draft step=1".into();
        let mut m1 = sim(&c_ctrl);
        let mut m2 = sim(&c_chaos);
        let mut e1 = engine(&c_ctrl);
        let mut e2 = engine(&c_chaos);
        for step in 0..3 {
            let r1 = e1.generate_step(&mut m1, &jobs(4, 2), step);
            let r2 = e2.generate_step(&mut m2, &jobs(4, 2), step);
            assert_eq!(
                sorted_rollouts(&r1),
                sorted_rollouts(&r2),
                "degraded outputs diverged at step {step}"
            );
            let expect = u64::from(step == 1);
            assert_eq!(r2.metrics.degraded_requests, expect, "gauge at step {step}");
            assert_eq!(r1.metrics.degraded_requests, 0, "control stays clean");
        }
    }

    #[test]
    fn injected_store_failure_disables_persistence_midrun() {
        // Degradation ladder rung 2: a store that starts failing at epoch 2
        // is dropped (counted once), and the run continues as if no store
        // had been configured — same outputs, store gauges zeroed.
        let dir = crate::store::test_dir("engine-store-fail");
        let mut c = cfg(0.0, "das", "uniform");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        c.rollout.fault_plan = "store-fail epoch=2".into();
        let mut c_ctrl = c.clone();
        c_ctrl.spec.store_dir = String::new();
        c_ctrl.rollout.fault_plan = String::new();
        let mut m = sim(&c);
        let mut m_ctrl = sim(&c_ctrl);
        let mut e = engine(&c);
        let mut e_ctrl = engine(&c_ctrl);
        let mut failures = 0u64;
        for step in 0..4u32 {
            e.roll_epoch(step);
            e_ctrl.roll_epoch(step);
            let rep = e.generate_step(&mut m, &jobs(3, 2), step);
            let ctrl = e_ctrl.generate_step(&mut m_ctrl, &jobs(3, 2), step);
            assert_eq!(sorted_rollouts(&rep), sorted_rollouts(&ctrl), "step {step}");
            failures += rep.metrics.store_failures;
            if step < 2 {
                assert!(e.store_status().is_some(), "store healthy before epoch 2");
            } else {
                assert!(e.store_status().is_none(), "sick store dropped at epoch 2");
                assert_eq!(rep.metrics.store_wal_records, 0, "gauges read from no store");
            }
            m.policy_update(1.0);
            m_ctrl.policy_update(1.0);
        }
        assert_eq!(failures, 1, "exactly one failure counted, at the disable point");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn suffix_drafter_learns_within_step() {
        // Even in the FIRST step, early-finishing samples of a problem seed
        // the tree for later samples of the same problem (online refresh).
        let c = cfg(0.0, "das", "uniform");
        let mut m = sim(&c);
        // Sharpen the policy so greedy paths repeat across samples.
        for _ in 0..60 {
            m.policy_update(1.0);
        }
        let mut e = engine(&c);
        let job = vec![GenJob {
            problem: 0,
            prompt: vec![1, 7, 9],
            samples: 6,
        }];
        let rep = e.generate_step(&mut m, &job, 0);
        assert!(
            rep.metrics.accepted > 0,
            "same-step reuse should already speculate"
        );
    }

    #[test]
    fn concurrent_drafting_is_lossless_across_substrates() {
        // Tentpole acceptance: snapshot drafting on worker threads may see
        // history one round staler than the live writer, but at T=0
        // losslessness pins every committed token — a concurrent run and a
        // forced-serial run must agree bit for bit, step after step, for
        // every substrate and for the frozen n-gram baseline.
        for (drafter, substrate) in
            [("das", "window"), ("das", "tree"), ("das", "array"), ("static", "window")]
        {
            let mut c_ser = cfg(0.0, drafter, "uniform");
            c_ser.spec.substrate = substrate.into();
            c_ser.spec.draft_threads = 1;
            let mut c_conc = c_ser.clone();
            c_conc.spec.draft_threads = 4;
            let mut m1 = sim(&c_ser);
            let mut m2 = sim(&c_conc);
            let mut e1 = engine(&c_ser);
            let mut e2 = engine(&c_conc);
            for step in 0..3 {
                e1.roll_epoch(step);
                e2.roll_epoch(step);
                let r1 = e1.generate_step(&mut m1, &jobs(4, 3), step);
                let r2 = e2.generate_step(&mut m2, &jobs(4, 3), step);
                assert_eq!(
                    sorted_rollouts(&r1),
                    sorted_rollouts(&r2),
                    "{drafter}/{substrate} diverged at step {step}"
                );
                assert_eq!(r1.metrics.completed, r2.metrics.completed);
                m1.policy_update(1.0);
                m2.policy_update(1.0);
            }
        }
    }

    #[test]
    fn concurrent_mode_records_snapshot_gauges() {
        let mut c = cfg(0.6, "das", "uniform");
        c.spec.draft_threads = 4;
        let mut m = sim(&c);
        let mut e = engine(&c);
        e.roll_epoch(0);
        let rep = e.generate_step(&mut m, &jobs(4, 3), 0);
        assert!(
            rep.metrics.index_snapshot_publishes > 0,
            "concurrent drafting must publish snapshots"
        );
        assert_eq!(
            rep.metrics.draft_snapshot_lag_epochs, 0,
            "publishes track the drafter's current epoch"
        );
        assert!(rep.metrics.index_nodes > 0, "per-step gauges stay populated");
    }

    #[test]
    fn poisoned_draft_under_concurrent_mode_stays_lossless() {
        // The chaos rung on the snapshot path: the one-shot poison panics
        // inside exactly one worker's catch_unwind; which request degrades
        // is scheduling-dependent, but the count is pinned at one and T=0
        // outputs never change.
        let mut c_ctrl = cfg(0.0, "das", "uniform");
        c_ctrl.spec.draft_threads = 4;
        let mut c_chaos = c_ctrl.clone();
        c_chaos.rollout.fault_plan = "poison-draft step=1".into();
        let mut m1 = sim(&c_ctrl);
        let mut m2 = sim(&c_chaos);
        let mut e1 = engine(&c_ctrl);
        let mut e2 = engine(&c_chaos);
        for step in 0..3 {
            let r1 = e1.generate_step(&mut m1, &jobs(4, 2), step);
            let r2 = e2.generate_step(&mut m2, &jobs(4, 2), step);
            assert_eq!(
                sorted_rollouts(&r1),
                sorted_rollouts(&r2),
                "degraded outputs diverged at step {step}"
            );
            let expect = u64::from(step == 1);
            assert_eq!(r2.metrics.degraded_requests, expect, "gauge at step {step}");
        }
    }

    #[test]
    fn concurrent_stress_many_readers_while_writer_absorbs() {
        // Satellite stress: eight reader threads over a queue deeper than
        // the batch, across epoch rolls and policy drift — every request
        // must complete with a well-formed rollout and no panics escape the
        // draft workers.
        let mut c = cfg(0.8, "das", "uniform");
        c.spec.draft_threads = 8;
        let mut m = sim(&c);
        let mut e = engine(&c);
        let mut total = 0u64;
        for step in 0..4u32 {
            e.roll_epoch(step);
            let rep = e.generate_step(&mut m, &jobs(6, 4), step);
            total += rep.metrics.completed;
            for r in &rep.rollouts {
                assert!(!r.tokens.is_empty());
                assert!(r.tokens.len() <= 128);
            }
            m.policy_update(1.0);
        }
        assert_eq!(total, 4 * 24, "no request lost under concurrent drafting");
    }

    #[test]
    fn poisoned_draft_host_degrades_chunk_not_step() {
        // Satellite regression: a reader HOST thread dying outside the
        // per-request catch_unwind used to abort the whole step through
        // `h.join().expect(...)`. Now the dead host's chunk degrades to
        // plain decoding, the step completes, and T=0 outputs are pinned.
        let mut c_ctrl = cfg(0.0, "das", "uniform");
        c_ctrl.spec.draft_threads = 4;
        let mut c_chaos = c_ctrl.clone();
        c_chaos.rollout.fault_plan = "poison-host step=1".into();
        let mut m1 = sim(&c_ctrl);
        let mut m2 = sim(&c_chaos);
        let mut e1 = engine(&c_ctrl);
        let mut e2 = engine(&c_chaos);
        for step in 0..3 {
            let r1 = e1.generate_step(&mut m1, &jobs(4, 2), step);
            let r2 = e2.generate_step(&mut m2, &jobs(4, 2), step);
            assert_eq!(
                sorted_rollouts(&r1),
                sorted_rollouts(&r2),
                "host death changed outputs at step {step}"
            );
            assert_eq!(r2.metrics.completed, 8, "step completes despite dead host");
            if step == 1 {
                assert!(
                    r2.metrics.degraded_requests >= 1,
                    "the dead host's whole chunk degrades"
                );
            } else {
                assert_eq!(r2.metrics.degraded_requests, 0, "one-shot fault");
            }
        }
    }

    #[test]
    fn freeze_migrate_resume_is_bit_identical_across_substrates() {
        // Tentpole acceptance at the engine seam: preempt a step at a
        // verification-round boundary, push every unfinished request
        // through the wire codec, resume on a DIFFERENT engine (fresh
        // drafter history, escalated budgets) — and the union of rollouts
        // must equal an uninterrupted control bit for bit, per substrate.
        for substrate in ["window", "tree", "array"] {
            let mut c = cfg(0.0, "das", "uniform");
            c.spec.substrate = substrate.into();
            let control = {
                let mut m = sim(&c);
                let mut e = engine(&c);
                sorted_rollouts(&e.generate_step(&mut m, &jobs(4, 2), 0))
            };
            let mut c_origin = c.clone();
            c_origin.rollout.fault_plan = "preempt worker=0 step=0".into();
            let mut m_origin = sim(&c_origin);
            let mut e_origin = engine(&c_origin);
            let rep = e_origin.generate_step(&mut m_origin, &jobs(4, 2), 0);
            assert_eq!(rep.metrics.preemptions, 1, "{substrate}: freeze fired");
            assert!(!rep.checkpoints.is_empty(), "{substrate}: in-flight work frozen");
            assert!(
                rep.checkpoints.iter().any(|ck| !ck.generated.is_empty()),
                "{substrate}: at least one request frozen mid-generation"
            );
            // Migration is a byte hop: everything the destination sees went
            // through the checksummed wire format.
            let thawed: Vec<RequestCheckpoint> = rep
                .checkpoints
                .iter()
                .map(|ck| {
                    let bytes = ck.to_bytes();
                    let back = RequestCheckpoint::from_bytes(&bytes).expect("round trip");
                    assert_eq!(&back, ck);
                    back
                })
                .collect();
            let mut m_dst = sim(&c);
            let mut e_dst = engine(&c);
            let resumed = e_dst.resume_step(&mut m_dst, &thawed, 0);
            assert_eq!(
                resumed.metrics.completed as usize,
                thawed.len(),
                "{substrate}: every migrated request finishes"
            );
            assert!(
                (resumed.metrics.resume_budget_boost - 2.0).abs() < 1e-12,
                "{substrate}: escalation gauge reports the configured boost"
            );
            let mut union: Vec<(u32, Vec<u32>)> = rep
                .rollouts
                .iter()
                .chain(resumed.rollouts.iter())
                .map(|r| (r.problem, r.tokens.clone()))
                .collect();
            union.sort();
            assert_eq!(
                union, control,
                "{substrate}: origin + resumed rollouts must equal the \
                 uninterrupted control exactly"
            );
        }
    }

    #[test]
    fn preempt_latch_freezes_at_round_boundary() {
        // The coordinator-facing seam: an armed latch (no fault plan)
        // freezes the step exactly once, and the latch reads cleared
        // afterwards so the next step runs normally.
        let c = cfg(0.0, "das", "uniform");
        let mut m = sim(&c);
        let mut e = engine(&c);
        let latch = Arc::new(AtomicBool::new(true));
        e.set_preempt_latch(Arc::clone(&latch));
        let rep = e.generate_step(&mut m, &jobs(4, 2), 0);
        assert_eq!(rep.metrics.preemptions, 1);
        assert!(!rep.checkpoints.is_empty());
        assert!(!latch.load(Ordering::Relaxed), "latch consumed by the freeze");
        // Next step: latch stays clear, no freeze.
        let rep2 = e.generate_step(&mut m, &jobs(4, 2), 1);
        assert_eq!(rep2.metrics.preemptions, 0);
        assert!(rep2.checkpoints.is_empty());
        assert_eq!(rep2.metrics.completed, 8);
    }

    #[test]
    fn resumed_degraded_request_stays_degraded() {
        // A request that fell off the speculation ladder before the freeze
        // must not silently re-arm its drafter on the destination: the
        // degraded flag rides the checkpoint.
        let c = cfg(0.0, "das", "uniform");
        let mut ck = {
            let mut c_origin = c.clone();
            c_origin.rollout.fault_plan = "preempt worker=0 step=0".into();
            let mut m = sim(&c_origin);
            let mut e = engine(&c_origin);
            let rep = e.generate_step(&mut m, &jobs(4, 2), 0);
            rep.checkpoints
                .into_iter()
                .find(|ck| !ck.generated.is_empty())
                .expect("mid-flight checkpoint")
        };
        ck.degraded = true;
        let mut m = sim(&c);
        let mut e = engine(&c);
        let resumed = e.resume_step(&mut m, &[ck], 0);
        assert_eq!(resumed.metrics.completed, 1);
        assert_eq!(
            resumed.metrics.proposed, 0,
            "a degraded request never speculates after migration"
        );
    }
}

//! Per-step rollout metrics — the raw series behind Figs. 1, 4, 6, 7, 10–13.

/// Metrics for one training step's generation phase.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    /// Model-clock generation time (virtual seconds for the simulator, wall
    /// seconds for PJRT) — the paper's "generation time per step".
    pub gen_time: f64,
    /// Wall-clock time spent inside the drafter (speculation overhead).
    pub draft_time: f64,
    /// Wall-clock of the whole generation phase (engine overhead incl.).
    pub wall_time: f64,
    /// Verification rounds executed (= forward passes, N_fwd).
    pub rounds: u64,
    /// Total tokens processed by the target model (accepted + speculative
    /// + bonus) — N_toks in Eq. 2.
    pub tokens_processed: u64,
    /// Draft tokens proposed / accepted.
    pub proposed: u64,
    pub accepted: u64,
    /// Tokens committed to rollouts (including EOS).
    pub generated: u64,
    /// Completed rollouts.
    pub completed: u64,
    /// Effective batch size at the start of every round (Fig. 1 trace).
    pub eff_batch: Vec<u32>,

    // --- drafter index gauges (end-of-step snapshots, not counters) ---
    // Summing across workers totals the fleet's index memory; across steps
    // only the latest snapshot is meaningful.
    /// Explicit (path-compressed) trie nodes across the drafter's indexes.
    pub index_nodes: u64,
    /// One-node-per-token equivalent positions (compression denominator).
    pub index_token_positions: u64,
    /// Index structure heap bytes (arenas + per-node stores).
    pub index_bytes: u64,
    /// Live interned segments in the drafter's shared label pool.
    pub pool_segments: u64,
    /// Live tokens held by the shared label pool.
    pub pool_tokens: u64,
    /// Approximate heap bytes of the shared label pool.
    pub pool_bytes: u64,
    /// Exact suffix-link rebuilds across the drafter's trie cores —
    /// compaction sweeps plus the insert-count refresh that keeps the
    /// never-compacting `window_all` path on exact links.
    pub index_link_rebuilds: u64,
    /// Distinct draft snapshots the drafter's indexes have published
    /// (cache misses only — unchanged republications are coalesced).
    pub index_snapshot_publishes: u64,
    /// Worst staleness of any snapshot the concurrent draft path read this
    /// step, in epochs behind the drafter's current epoch (0 = every draft
    /// saw the current epoch's publish; serial drafting leaves it 0).
    pub draft_snapshot_lag_epochs: u64,

    // --- persistent history store gauges (0 when no store is configured) ---
    /// Payload bytes of the last committed (or warm-start-loaded) snapshot.
    pub store_snapshot_bytes: u64,
    /// WAL records accumulated since the last snapshot commit.
    pub store_wal_records: u64,
    /// WAL bytes accumulated since the last snapshot commit.
    pub store_wal_bytes: u64,
    /// Wall seconds the last snapshot commit took (0 until one happens).
    pub store_persist_s: f64,

    // --- fault-tolerance counters (supervised pool + degradation ladder) ---
    // Every recovery the supervisor performs is visible here; an all-zero
    // row means the step ran clean.
    /// Worker threads respawned after a panic (coordinator-side).
    pub worker_restarts: u64,
    /// Jobs re-dispatched off a dead worker's in-flight chunk.
    pub jobs_redispatched: u64,
    /// Queued jobs moved from a straggler to an idle worker by the
    /// deadline policy (work stealing).
    pub deadline_steals: u64,
    /// Requests whose drafter errored mid-step and fell back to plain
    /// (non-speculative) decoding for the rest of the request.
    pub degraded_requests: u64,
    /// Store write failures that disabled persistence mid-run.
    pub store_failures: u64,

    // --- preemption / migration (straggler shaping) ---
    /// In-flight chunks frozen off a deadline-blown (or fault-injected)
    /// straggler at a verification-round boundary.
    pub preemptions: u64,
    /// Checkpointed requests re-dispatched to another worker and resumed.
    pub migrated_requests: u64,
    /// The speculative-budget multiplier applied to resumed requests this
    /// step (gauge; 0 until a migration happens, then the configured boost).
    pub resume_budget_boost: f64,
    /// Measured step wall time over the LPT-with-perfect-lengths lower
    /// bound (total per-chunk device time / workers): 1.0 = the schedule
    /// was as good as an oracle packing, higher = makespan left on the
    /// table by stragglers. 0 until the coordinator computes it.
    pub makespan_vs_oracle: f64,

    // --- remote draft service (all zero unless spec.substrate = "remote") ---
    /// RPC round-trips completed against the `das serve-drafts` daemon.
    pub remote_round_trips: u64,
    /// Draft contexts answered remotely (batched requests count each
    /// context, so this / `remote_round_trips` is the realized batch size).
    pub remote_contexts: u64,
    /// Remote RPC attempts that hit the connect/read/write deadline.
    pub remote_timeouts: u64,
    /// Successful re-dials after a lost or failed connection.
    pub remote_reconnects: u64,
    /// Remote calls that exhausted the retry ladder (or hit a dead
    /// session) and degraded to plain decoding.
    pub remote_degraded: u64,
    /// RPC latency quantiles over this step's round-trips, in seconds
    /// (gauges; 0 until remote traffic happens).
    pub remote_rpc_p50_s: f64,
    pub remote_rpc_p99_s: f64,
}

impl StepMetrics {
    /// Fraction of proposed draft tokens accepted.
    pub fn accept_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Average accepted draft tokens per verification round — the y-axis of
    /// Figs. 4, 6, 7. (Counts only rounds where speculation ran.)
    pub fn accepted_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.accepted as f64 / self.rounds as f64
        }
    }

    /// Committed tokens per forward pass (≥ 1; the speedup mechanism).
    pub fn tokens_per_pass(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.generated as f64 / self.rounds as f64
        }
    }

    /// Speculation latency per generated token in ms (Figs. 6/7 right).
    pub fn draft_ms_per_token(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.draft_time * 1e3 / self.generated as f64
        }
    }

    pub fn merge(&mut self, other: &StepMetrics) {
        self.gen_time += other.gen_time;
        self.draft_time += other.draft_time;
        self.wall_time += other.wall_time;
        self.rounds += other.rounds;
        self.tokens_processed += other.tokens_processed;
        self.proposed += other.proposed;
        self.accepted += other.accepted;
        self.generated += other.generated;
        self.completed += other.completed;
        self.eff_batch.extend_from_slice(&other.eff_batch);
        // Gauges sum: merging worker reports totals the fleet's memory.
        self.index_nodes += other.index_nodes;
        self.index_token_positions += other.index_token_positions;
        self.index_bytes += other.index_bytes;
        self.pool_segments += other.pool_segments;
        self.pool_tokens += other.pool_tokens;
        self.pool_bytes += other.pool_bytes;
        self.index_link_rebuilds += other.index_link_rebuilds;
        self.index_snapshot_publishes += other.index_snapshot_publishes;
        // Staleness is a worst-case gauge, not a fleet total.
        self.draft_snapshot_lag_epochs =
            self.draft_snapshot_lag_epochs.max(other.draft_snapshot_lag_epochs);
        self.store_snapshot_bytes += other.store_snapshot_bytes;
        self.store_wal_records += other.store_wal_records;
        self.store_wal_bytes += other.store_wal_bytes;
        // Persist latency is a per-store duration, not a fleet total: the
        // merged view keeps the straggler (commits run inside epoch rolls,
        // so the slowest worker's commit is the one the learner waits on).
        self.store_persist_s = self.store_persist_s.max(other.store_persist_s);
        self.worker_restarts += other.worker_restarts;
        self.jobs_redispatched += other.jobs_redispatched;
        self.deadline_steals += other.deadline_steals;
        self.degraded_requests += other.degraded_requests;
        self.store_failures += other.store_failures;
        self.preemptions += other.preemptions;
        self.migrated_requests += other.migrated_requests;
        // Per-step gauges, not fleet totals: keep the worst observation.
        self.resume_budget_boost = self.resume_budget_boost.max(other.resume_budget_boost);
        self.makespan_vs_oracle = self.makespan_vs_oracle.max(other.makespan_vs_oracle);
        self.remote_round_trips += other.remote_round_trips;
        self.remote_contexts += other.remote_contexts;
        self.remote_timeouts += other.remote_timeouts;
        self.remote_reconnects += other.remote_reconnects;
        self.remote_degraded += other.remote_degraded;
        // Latency quantiles are per-session gauges; the merged view keeps
        // the slowest session (the one gating step latency).
        self.remote_rpc_p50_s = self.remote_rpc_p50_s.max(other.remote_rpc_p50_s);
        self.remote_rpc_p99_s = self.remote_rpc_p99_s.max(other.remote_rpc_p99_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let m = StepMetrics {
            proposed: 100,
            accepted: 60,
            rounds: 30,
            generated: 90,
            draft_time: 0.009,
            ..Default::default()
        };
        assert!((m.accept_rate() - 0.6).abs() < 1e-12);
        assert!((m.accepted_per_round() - 2.0).abs() < 1e-12);
        assert!((m.tokens_per_pass() - 3.0).abs() < 1e-12);
        assert!((m.draft_ms_per_token() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_safe() {
        let m = StepMetrics::default();
        assert_eq!(m.accept_rate(), 0.0);
        assert_eq!(m.accepted_per_round(), 0.0);
        assert_eq!(m.tokens_per_pass(), 0.0);
        assert_eq!(m.draft_ms_per_token(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = StepMetrics {
            rounds: 1,
            eff_batch: vec![4],
            ..Default::default()
        };
        let b = StepMetrics {
            rounds: 2,
            eff_batch: vec![3, 2],
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.rounds, 3);
        assert_eq!(a.eff_batch, vec![4, 3, 2]);
    }

    #[test]
    fn merge_sums_fault_tolerance_counters() {
        let mut a = StepMetrics {
            worker_restarts: 1,
            jobs_redispatched: 3,
            deadline_steals: 2,
            degraded_requests: 1,
            store_failures: 0,
            preemptions: 1,
            migrated_requests: 2,
            ..Default::default()
        };
        let b = StepMetrics {
            worker_restarts: 2,
            jobs_redispatched: 1,
            deadline_steals: 0,
            degraded_requests: 4,
            store_failures: 1,
            preemptions: 2,
            migrated_requests: 5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.worker_restarts, 3);
        assert_eq!(a.jobs_redispatched, 4);
        assert_eq!(a.deadline_steals, 2);
        assert_eq!(a.degraded_requests, 5);
        assert_eq!(a.store_failures, 1);
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.migrated_requests, 7);
    }

    #[test]
    fn merge_combines_remote_draft_metrics() {
        let mut a = StepMetrics {
            remote_round_trips: 4,
            remote_contexts: 16,
            remote_timeouts: 1,
            remote_reconnects: 1,
            remote_degraded: 0,
            remote_rpc_p50_s: 0.002,
            remote_rpc_p99_s: 0.010,
            ..Default::default()
        };
        let b = StepMetrics {
            remote_round_trips: 2,
            remote_contexts: 2,
            remote_timeouts: 0,
            remote_reconnects: 0,
            remote_degraded: 3,
            remote_rpc_p50_s: 0.001,
            remote_rpc_p99_s: 0.030,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.remote_round_trips, 6);
        assert_eq!(a.remote_contexts, 18);
        assert_eq!(a.remote_timeouts, 1);
        assert_eq!(a.remote_reconnects, 1);
        assert_eq!(a.remote_degraded, 3);
        assert!((a.remote_rpc_p50_s - 0.002).abs() < 1e-12, "slowest session wins");
        assert!((a.remote_rpc_p99_s - 0.030).abs() < 1e-12);
    }

    #[test]
    fn merge_keeps_worst_scheduling_gauges() {
        let mut a = StepMetrics {
            resume_budget_boost: 2.0,
            makespan_vs_oracle: 1.1,
            ..Default::default()
        };
        let b = StepMetrics {
            resume_budget_boost: 0.0,
            makespan_vs_oracle: 1.7,
            ..Default::default()
        };
        a.merge(&b);
        assert!((a.resume_budget_boost - 2.0).abs() < 1e-12);
        assert!((a.makespan_vs_oracle - 1.7).abs() < 1e-12);
    }
}

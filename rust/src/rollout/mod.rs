//! Rollout coordination: continuous batching + the speculative decode loop.

pub mod batcher;
pub mod parallel;
pub mod engine;
pub mod faults;
pub mod metrics;
pub mod request;

pub use batcher::Batcher;
pub use parallel::{verify_coordinator_sidecar, DataParallelRollout, ParallelStepReport};
pub use engine::{BudgetPolicy, GenJob, RolloutEngine, StepReport};
pub use faults::FaultPlan;
pub use metrics::StepMetrics;
pub use request::{RequestCheckpoint, RequestState, RolloutRequest};

//! Rollout coordination: continuous batching + the speculative decode loop.

// Clippy backstop for the audit's panic-path rule: rollout code is
// supervised — panics are for injected faults only (each carries a
// reasoned `audit: allow` pragma); everything else degrades. The deny
// cascades into every child module, so new unwrap/expect sites fail lint.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod batcher;
pub mod parallel;
pub mod engine;
pub mod faults;
pub mod metrics;
pub mod request;

pub use batcher::Batcher;
pub use parallel::{verify_coordinator_sidecar, DataParallelRollout, ParallelStepReport};
pub use engine::{BudgetPolicy, GenJob, RolloutEngine, StepReport};
pub use faults::FaultPlan;
pub use metrics::StepMetrics;
pub use request::{RequestCheckpoint, RequestState, RolloutRequest};

//! Data-parallel rollout workers (§3: "systems like VeRL and OpenRLHF
//! favor data-parallel rollout workers to scale decoding throughput").
//!
//! A [`DataParallelRollout`] owns `n` **persistent** worker replicas — each
//! an OS thread holding a policy replica plus its own [`RolloutEngine`]
//! (drafter state is worker-local, exactly like per-actor suffix trees in
//! the paper's deployment). Threads and channels are created ONCE in
//! [`DataParallelRollout::new`]; every `generate_step` just enqueues a shard
//! per worker and collects reports, so per-step coordination cost is two
//! channel hops instead of `n` thread spawns/joins. Epoch rolls and policy
//! updates ride the same command queues, which keeps them ordered with
//! respect to steps without any locking.
//!
//! The step's *makespan* is the slowest worker's generation time, which is
//! precisely where the long-tail problem bites at the cluster level: one
//! straggler worker holds up the learner. Jobs are therefore sharded
//! longest-predicted-first onto the least-loaded worker (LPT — the paper's
//! own makespan argument, §3/Fig. 12, applied across workers) using the
//! same length statistics that drive the speculation budget, instead of
//! blind round-robin. The LPT cost key folds in per-problem *acceptance*
//! history too (each worker report carries its finished requests'
//! speculation outcomes): a long problem whose drafts are mostly accepted
//! finishes in far fewer target forwards than its raw length suggests, and
//! weighting it by length alone would over-pack it. DAS shrinks per-worker
//! tails, so it compresses the cross-worker makespan too (test below).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::{self, JoinHandle};

use super::engine::{GenJob, RolloutEngine, StepReport};
use super::metrics::StepMetrics;
use crate::config::DasConfig;
use crate::model::sim::{SimModel, SimModelConfig};
use crate::spec::LengthPolicy;
use crate::tokens::{Epoch, Rollout};

pub struct DataParallelRollout {
    workers: Vec<WorkerHandle>,
    /// Coordinator-side length statistics feeding the LPT sharder (fed by
    /// every finished rollout; the same survival-statistics predictor the
    /// engines use for speculation budgets).
    predictor: LengthPolicy,
}

enum Command {
    Step { jobs: Vec<GenJob>, step: u32 },
    RollEpoch(Epoch),
    PolicyUpdate(f64),
    Shutdown,
}

struct WorkerHandle {
    cmd_tx: Sender<Command>,
    report_rx: Receiver<StepReport>,
    thread: Option<JoinHandle<()>>,
}

/// Merged outcome of one data-parallel step.
#[derive(Debug)]
pub struct ParallelStepReport {
    pub rollouts: Vec<Rollout>,
    /// Slowest worker's generation time — the step latency the learner sees.
    pub makespan: f64,
    /// Sum of worker generation times (device-seconds; utilization proxy).
    pub total_device_time: f64,
    pub per_worker: Vec<StepMetrics>,
}

/// Longest-processing-time-first assignment: jobs (by predicted cost) are
/// placed heaviest-first onto the currently least-loaded worker. Returns a
/// worker index per job. Deterministic: cost ties keep submission order,
/// load ties pick the lowest worker index.
fn lpt_assignment(costs: &[f64], n_workers: usize) -> Vec<usize> {
    let n = n_workers.max(1);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| {
        costs[b]
            .partial_cmp(&costs[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut assignment = vec![0usize; costs.len()];
    let mut load = vec![0.0f64; n];
    for job in order {
        let mut best = 0usize;
        for w in 1..n {
            if load[w] < load[best] {
                best = w;
            }
        }
        assignment[job] = best;
        // Floor at 1 so zero-cost predictions still spread across workers.
        load[best] += costs[job].max(1.0);
    }
    assignment
}

impl DataParallelRollout {
    /// Build `n_workers` replicas ONCE: each worker thread owns its policy
    /// replica and engine for the lifetime of the pool. Policy replicas
    /// share the seed (data parallelism: same weights everywhere); engines
    /// get distinct request id spaces via the config seed offset so RNG
    /// streams never collide.
    pub fn new(cfg: &DasConfig, n_workers: usize) -> Self {
        let workers = (0..n_workers.max(1))
            .map(|w| {
                let mut wcfg = cfg.clone();
                // Worker-local engine seed: shifts request RNG forks, not
                // the policy (the sim replica keeps the shared seed).
                wcfg.seed = cfg.seed ^ ((w as u64 + 1) << 32);
                // Worker-local history store: drafters are worker-local, so
                // each persists (and warm-starts) under its own
                // subdirectory — resuming with the same worker count
                // restores every replica's history.
                if !wcfg.spec.store_dir.is_empty() {
                    wcfg.spec.store_dir = format!("{}/worker{w}", wcfg.spec.store_dir);
                }
                let model_cfg = SimModelConfig::from_das(cfg);
                let (cmd_tx, cmd_rx) = channel::<Command>();
                let (report_tx, report_rx) = channel::<StepReport>();
                let thread = thread::Builder::new()
                    .name(format!("dp-worker-{w}"))
                    .spawn(move || {
                        let mut model = SimModel::new(model_cfg);
                        let mut engine =
                            RolloutEngine::new(&wcfg, crate::drafter::from_config(&wcfg));
                        while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Command::Step { jobs, step } => {
                                    let report = engine.generate_step(&mut model, &jobs, step);
                                    if report_tx.send(report).is_err() {
                                        break;
                                    }
                                }
                                Command::RollEpoch(e) => engine.roll_epoch(e),
                                Command::PolicyUpdate(gain) => model.policy_update(gain),
                                Command::Shutdown => break,
                            }
                        }
                    })
                    .expect("spawn rollout worker thread");
                WorkerHandle {
                    cmd_tx,
                    report_rx,
                    thread: Some(thread),
                }
            })
            .collect();
        DataParallelRollout {
            workers,
            // Same thresholds as the worker engines, so the coordinator's
            // LPT keys classify lengths exactly like the engines do.
            predictor: LengthPolicy::from_das(cfg),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Advance every replica's epoch (window maintenance). Enqueued on the
    /// command channels, so it is ordered with respect to steps.
    pub fn roll_epoch(&mut self, epoch: u32) {
        for w in &self.workers {
            w.cmd_tx
                .send(Command::RollEpoch(epoch))
                .expect("worker alive");
        }
    }

    /// Apply the learner update to every policy replica (data parallelism:
    /// identical weights — the sim replicas share seed, so drift stays
    /// bit-identical across workers).
    pub fn policy_update(&mut self, gain: f64) {
        for w in &self.workers {
            w.cmd_tx
                .send(Command::PolicyUpdate(gain))
                .expect("worker alive");
        }
    }

    /// Shard `jobs` longest-predicted-first and run all workers
    /// concurrently on the persistent pool.
    pub fn generate_step(&mut self, jobs: &[GenJob], step: u32) -> ParallelStepReport {
        let n = self.workers.len();
        let costs: Vec<f64> = jobs
            .iter()
            .map(|j| self.predictor.job_cost(j.problem, j.samples))
            .collect();
        let assignment = lpt_assignment(&costs, n);
        let mut shards: Vec<Vec<GenJob>> = vec![Vec::new(); n];
        for (job, &w) in jobs.iter().zip(&assignment) {
            shards[w].push(job.clone());
        }
        for (worker, shard) in self.workers.iter().zip(shards) {
            worker
                .cmd_tx
                .send(Command::Step { jobs: shard, step })
                .expect("worker alive");
        }
        let reports: Vec<StepReport> = self
            .workers
            .iter()
            .map(|w| w.report_rx.recv().expect("worker panicked"))
            .collect();
        let makespan = reports
            .iter()
            .map(|r| r.metrics.gen_time)
            .fold(0.0_f64, f64::max);
        let total_device_time: f64 = reports.iter().map(|r| r.metrics.gen_time).sum();
        let mut rollouts = Vec::new();
        let mut per_worker = Vec::new();
        for r in reports {
            for roll in &r.rollouts {
                // Feed the LPT predictor with every observed final length.
                self.predictor.observe(roll.problem, roll.tokens.len());
            }
            // …and with every request's speculation outcome, so the cost
            // key discounts problems that speculate well.
            for &(problem, rounds, accepted) in &r.accept_obs {
                self.predictor.observe_acceptance(problem, rounds, accepted);
            }
            rollouts.extend(r.rollouts);
            per_worker.push(r.metrics);
        }
        ParallelStepReport {
            rollouts,
            makespan,
            total_device_time,
            per_worker,
        }
    }
}

impl Drop for DataParallelRollout {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(Command::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DasConfig;

    fn cfg(drafter: &str) -> DasConfig {
        let mut c = DasConfig::default();
        c.model.vocab_size = 128;
        c.workload.n_problems = 12;
        c.workload.len_mu = 3.6;
        c.workload.len_sigma = 0.6;
        c.rollout.max_new_tokens = 160;
        c.rollout.max_batch = 4;
        c.rollout.temperature = 0.0; // greedy: sharding-invariant outputs
        c.spec.drafter = drafter.into();
        c
    }

    fn jobs(n: u32) -> Vec<GenJob> {
        (0..n)
            .map(|p| GenJob {
                problem: p,
                prompt: vec![p + 1, 7],
                samples: 2,
            })
            .collect()
    }

    #[test]
    fn sharding_preserves_greedy_outputs() {
        // The same greedy rollouts regardless of worker count — data
        // parallelism must be semantically invisible.
        let key = |r: &Rollout| (r.problem, r.tokens.clone());
        let mut single = DataParallelRollout::new(&cfg("none"), 1);
        let mut quad = DataParallelRollout::new(&cfg("none"), 4);
        let a = single.generate_step(&jobs(12), 0);
        let b = quad.generate_step(&jobs(12), 0);
        let mut ka: Vec<_> = a.rollouts.iter().map(key).collect();
        let mut kb: Vec<_> = b.rollouts.iter().map(key).collect();
        ka.sort();
        kb.sort();
        assert_eq!(ka, kb);
        assert_eq!(kb.len(), 24);
    }

    #[test]
    fn makespan_is_max_and_device_time_is_sum() {
        let mut dp = DataParallelRollout::new(&cfg("none"), 3);
        let rep = dp.generate_step(&jobs(9), 0);
        let max = rep
            .per_worker
            .iter()
            .map(|m| m.gen_time)
            .fold(0.0_f64, f64::max);
        let sum: f64 = rep.per_worker.iter().map(|m| m.gen_time).sum();
        assert!((rep.makespan - max).abs() < 1e-12);
        assert!((rep.total_device_time - sum).abs() < 1e-12);
        assert!(rep.makespan <= rep.total_device_time);
    }

    #[test]
    fn das_compresses_cross_worker_makespan() {
        // The cluster-level claim: with DAS, the slowest worker finishes
        // sooner once drafters are warm.
        let run = |drafter: &str| -> f64 {
            let mut dp = DataParallelRollout::new(&cfg(drafter), 4);
            let mut makespan = 0.0;
            for step in 0..5 {
                let rep = dp.generate_step(&jobs(12), step);
                if step >= 2 {
                    makespan += rep.makespan;
                }
                dp.policy_update(1.0);
                dp.roll_epoch(step + 1);
            }
            makespan
        };
        let base = run("none");
        let das = run("das");
        assert!(
            das < base,
            "DAS should cut the DP makespan: das={das:.3} base={base:.3}"
        );
    }

    #[test]
    fn uneven_shards_handled() {
        let mut dp = DataParallelRollout::new(&cfg("das"), 4);
        // 5 jobs over 4 workers; one worker gets 2, no worker idles forever.
        let rep = dp.generate_step(&jobs(5), 0);
        assert_eq!(rep.rollouts.len(), 10);
        assert_eq!(rep.per_worker.len(), 4);
    }

    #[test]
    fn pool_survives_many_steps_and_maintenance() {
        // Persistent workers: the same threads serve every step, with epoch
        // rolls and policy updates ordered in between.
        let mut dp = DataParallelRollout::new(&cfg("das"), 2);
        for step in 0..4 {
            let rep = dp.generate_step(&jobs(6), step);
            assert_eq!(rep.rollouts.len(), 12, "step {step}");
            dp.policy_update(1.0);
            dp.roll_epoch(step + 1);
        }
        assert_eq!(dp.n_workers(), 2);
    }

    #[test]
    fn coordinator_predictor_absorbs_acceptance() {
        // The coordinator's LPT predictor must see both halves of the cost
        // key from worker reports: final lengths AND speculation outcomes.
        // No policy updates: step-1 greedy paths replay step-0 rollouts
        // exactly, so at least the stably-assigned problems must accept.
        let mut dp = DataParallelRollout::new(&cfg("das"), 2);
        for step in 0..3 {
            dp.generate_step(&jobs(6), step);
        }
        let with_acceptance: f64 = (0..6).map(|p| dp.predictor.job_cost(p, 2)).sum();
        let length_only: f64 = (0..6)
            .map(|p| {
                dp.predictor.job_cost(p, 2) * (1.0 + dp.predictor.accepted_per_round(p))
            })
            .sum();
        assert!(
            with_acceptance < length_only,
            "after warm steps some problem must speculate and discount its key: {with_acceptance} vs {length_only}"
        );
    }

    #[test]
    fn dp_two_phase_warm_start_restores_worker_history() {
        // Per-worker stores under <dir>/worker<i>: kill the pool after two
        // steps, rebuild it, and the resumed run must report restored
        // history on its first step while producing the same greedy
        // rollouts as a never-killed control pool.
        let dir = crate::store::test_dir("dp-two-phase");
        let mut c = cfg("das");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        c.spec.snapshot_every = 1;
        let mut c_ctrl = c.clone();
        c_ctrl.spec.store_dir = String::new();
        let key = |r: &Rollout| (r.problem, r.tokens.clone());
        let mut control = Vec::new();
        {
            let mut dp = DataParallelRollout::new(&c_ctrl, 2);
            for step in 0..4 {
                dp.roll_epoch(step);
                let rep = dp.generate_step(&jobs(6), step);
                let mut k: Vec<_> = rep.rollouts.iter().map(key).collect();
                k.sort();
                control.push(k);
            }
        }
        {
            let mut dp = DataParallelRollout::new(&c, 2);
            for step in 0..2 {
                dp.roll_epoch(step);
                dp.generate_step(&jobs(6), step);
            }
        } // kill: Drop joins the workers, so all persists have landed
        assert!(
            dir.join("worker0").exists() && dir.join("worker1").exists(),
            "one store per worker"
        );
        let mut dp = DataParallelRollout::new(&c, 2);
        for step in 2..4u32 {
            dp.roll_epoch(step);
            let rep = dp.generate_step(&jobs(6), step);
            if step == 2 {
                let restored: u64 = rep
                    .per_worker
                    .iter()
                    .map(|m| m.index_token_positions)
                    .sum();
                assert!(restored > 0, "first resumed step reports restored history");
            }
            let mut k: Vec<_> = rep.rollouts.iter().map(key).collect();
            k.sort();
            assert_eq!(k, control[step as usize], "resumed rollouts match control");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lpt_beats_round_robin_makespan() {
        // The scheduling argument in isolation: on a skewed cost vector,
        // LPT's worst worker is no worse than round-robin's (and strictly
        // better here).
        let costs = [8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let n = 2;
        let lpt = lpt_assignment(&costs, n);
        let span = |assign: &dyn Fn(usize) -> usize| -> f64 {
            let mut load = vec![0.0; n];
            for (i, &c) in costs.iter().enumerate() {
                load[assign(i)] += c;
            }
            load.iter().fold(0.0_f64, |a, &b| a.max(b))
        };
        let lpt_span = span(&|i| lpt[i]);
        let rr_span = span(&|i| i % n);
        assert!(lpt_span <= rr_span, "lpt={lpt_span} rr={rr_span}");
        assert!((lpt_span - 18.0).abs() < 1e-12, "LPT makespan on this vector is 18");
        assert!((rr_span - 20.0).abs() < 1e-12, "round-robin makespan is 20");
    }

    #[test]
    fn lpt_spreads_equal_costs_evenly() {
        // Cold start (no length history): every job predicts the same cost,
        // and LPT must still balance counts like round-robin would.
        let costs = vec![5.0; 10];
        let assign = lpt_assignment(&costs, 4);
        let mut per_worker = [0usize; 4];
        for &w in &assign {
            per_worker[w] += 1;
        }
        assert_eq!(per_worker.iter().max(), Some(&3));
        assert_eq!(per_worker.iter().min(), Some(&2));
    }
}

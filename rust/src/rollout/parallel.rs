//! Data-parallel rollout workers (§3: "systems like VeRL and OpenRLHF
//! favor data-parallel rollout workers to scale decoding throughput").
//!
//! A [`DataParallelRollout`] owns `n` worker replicas — each a policy
//! replica plus its own [`RolloutEngine`] (drafter state is worker-local,
//! exactly like per-actor suffix trees in the paper's deployment) — and
//! shards each step's jobs across them. Workers run on OS threads; the
//! step's *makespan* is the slowest worker's generation time, which is
//! precisely where the long-tail problem bites at the cluster level: one
//! straggler worker holds up the learner. DAS shrinks per-worker tails, so
//! it compresses the cross-worker makespan too (test below).

use std::thread;

use super::engine::{GenJob, RolloutEngine, StepReport};
use super::metrics::StepMetrics;
use crate::config::DasConfig;
use crate::model::sim::{SimModel, SimModelConfig};
use crate::tokens::Rollout;

pub struct DataParallelRollout {
    workers: Vec<Worker>,
}

struct Worker {
    model: SimModel,
    engine: RolloutEngine,
}

/// Merged outcome of one data-parallel step.
#[derive(Debug)]
pub struct ParallelStepReport {
    pub rollouts: Vec<Rollout>,
    /// Slowest worker's generation time — the step latency the learner sees.
    pub makespan: f64,
    /// Sum of worker generation times (device-seconds; utilization proxy).
    pub total_device_time: f64,
    pub per_worker: Vec<StepMetrics>,
}

impl DataParallelRollout {
    /// Build `n_workers` replicas. Policy replicas share the seed (data
    /// parallelism: same weights everywhere); engines get distinct request
    /// id spaces via the config seed offset so RNG streams never collide.
    pub fn new(cfg: &DasConfig, n_workers: usize) -> Self {
        let workers = (0..n_workers.max(1))
            .map(|w| {
                let mut wcfg = cfg.clone();
                // Worker-local engine seed: shifts request RNG forks, not
                // the policy (the sim replica keeps the shared seed).
                wcfg.seed = cfg.seed ^ ((w as u64 + 1) << 32);
                let model = SimModel::new(SimModelConfig::from_das(cfg));
                let engine = RolloutEngine::new(&wcfg, crate::drafter::from_config(&wcfg));
                Worker { model, engine }
            })
            .collect();
        DataParallelRollout { workers }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Advance every replica's epoch (window maintenance).
    pub fn roll_epoch(&mut self, epoch: u32) {
        for w in &mut self.workers {
            w.engine.roll_epoch(epoch);
        }
    }

    /// Apply the learner update to every policy replica (data parallelism:
    /// identical weights — the sim replicas share seed, so drift stays
    /// bit-identical across workers).
    pub fn policy_update(&mut self, gain: f64) {
        for w in &mut self.workers {
            w.model.policy_update(gain);
        }
    }

    /// Shard `jobs` round-robin and run all workers concurrently.
    pub fn generate_step(&mut self, jobs: &[GenJob], step: u32) -> ParallelStepReport {
        let n = self.workers.len();
        let mut shards: Vec<Vec<GenJob>> = vec![Vec::new(); n];
        for (i, job) in jobs.iter().enumerate() {
            shards[i % n].push(job.clone());
        }
        let reports: Vec<StepReport> = thread::scope(|scope| {
            let handles: Vec<_> = self
                .workers
                .iter_mut()
                .zip(shards)
                .map(|(w, shard)| {
                    scope.spawn(move || w.engine.generate_step(&mut w.model, &shard, step))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let makespan = reports
            .iter()
            .map(|r| r.metrics.gen_time)
            .fold(0.0_f64, f64::max);
        let total_device_time: f64 = reports.iter().map(|r| r.metrics.gen_time).sum();
        let mut rollouts = Vec::new();
        let mut per_worker = Vec::new();
        for r in reports {
            rollouts.extend(r.rollouts);
            per_worker.push(r.metrics);
        }
        ParallelStepReport {
            rollouts,
            makespan,
            total_device_time,
            per_worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DasConfig;

    fn cfg(drafter: &str) -> DasConfig {
        let mut c = DasConfig::default();
        c.model.vocab_size = 128;
        c.workload.n_problems = 12;
        c.workload.len_mu = 3.6;
        c.workload.len_sigma = 0.6;
        c.rollout.max_new_tokens = 160;
        c.rollout.max_batch = 4;
        c.rollout.temperature = 0.0; // greedy: sharding-invariant outputs
        c.spec.drafter = drafter.into();
        c
    }

    fn jobs(n: u32) -> Vec<GenJob> {
        (0..n)
            .map(|p| GenJob {
                problem: p,
                prompt: vec![p + 1, 7],
                samples: 2,
            })
            .collect()
    }

    #[test]
    fn sharding_preserves_greedy_outputs() {
        // The same greedy rollouts regardless of worker count — data
        // parallelism must be semantically invisible.
        let key = |r: &Rollout| (r.problem, r.tokens.clone());
        let mut single = DataParallelRollout::new(&cfg("none"), 1);
        let mut quad = DataParallelRollout::new(&cfg("none"), 4);
        let a = single.generate_step(&jobs(12), 0);
        let b = quad.generate_step(&jobs(12), 0);
        let mut ka: Vec<_> = a.rollouts.iter().map(key).collect();
        let mut kb: Vec<_> = b.rollouts.iter().map(key).collect();
        ka.sort();
        kb.sort();
        assert_eq!(ka, kb);
        assert_eq!(kb.len(), 24);
    }

    #[test]
    fn makespan_is_max_and_device_time_is_sum() {
        let mut dp = DataParallelRollout::new(&cfg("none"), 3);
        let rep = dp.generate_step(&jobs(9), 0);
        let max = rep
            .per_worker
            .iter()
            .map(|m| m.gen_time)
            .fold(0.0_f64, f64::max);
        let sum: f64 = rep.per_worker.iter().map(|m| m.gen_time).sum();
        assert!((rep.makespan - max).abs() < 1e-12);
        assert!((rep.total_device_time - sum).abs() < 1e-12);
        assert!(rep.makespan <= rep.total_device_time);
    }

    #[test]
    fn das_compresses_cross_worker_makespan() {
        // The cluster-level claim: with DAS, the slowest worker finishes
        // sooner once drafters are warm.
        let run = |drafter: &str| -> f64 {
            let mut dp = DataParallelRollout::new(&cfg(drafter), 4);
            let mut makespan = 0.0;
            for step in 0..5 {
                let rep = dp.generate_step(&jobs(12), step);
                if step >= 2 {
                    makespan += rep.makespan;
                }
                dp.policy_update(1.0);
                dp.roll_epoch(step + 1);
            }
            makespan
        };
        let base = run("none");
        let das = run("das");
        assert!(
            das < base,
            "DAS should cut the DP makespan: das={das:.3} base={base:.3}"
        );
    }

    #[test]
    fn uneven_shards_handled() {
        let mut dp = DataParallelRollout::new(&cfg("das"), 4);
        // 5 jobs over 4 workers; one worker gets 2, no worker idles forever.
        let rep = dp.generate_step(&jobs(5), 0);
        assert_eq!(rep.rollouts.len(), 10);
        assert_eq!(rep.per_worker.len(), 4);
    }
}

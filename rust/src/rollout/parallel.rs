//! Data-parallel rollout workers (§3: "systems like VeRL and OpenRLHF
//! favor data-parallel rollout workers to scale decoding throughput").
//!
//! A [`DataParallelRollout`] owns `n` **persistent** worker replicas — each
//! an OS thread holding a policy replica plus its own [`RolloutEngine`]
//! (drafter state is worker-local, exactly like per-actor suffix trees in
//! the paper's deployment). Threads and channels are created ONCE in
//! [`DataParallelRollout::new`]; epoch rolls and policy updates ride the
//! same command queues as work, which keeps them ordered with respect to
//! steps without any locking.
//!
//! Two levels of parallelism compose here: data parallelism across worker
//! replicas (this module), and snapshot-read parallelism inside each
//! replica's decode loop (`spec.draft_threads` — the engine drafts on
//! reader threads against a published [`crate::drafter::DrafterSnapshot`]
//! while its writer half absorbs finished rollouts). Worker-local drafter
//! state means the levels never share mutable structures.
//!
//! The step's *makespan* is the slowest worker's generation time, which is
//! precisely where the long-tail problem bites at the cluster level: one
//! straggler worker holds up the learner. Jobs are therefore sharded
//! longest-predicted-first onto the least-loaded worker (LPT — the paper's
//! own makespan argument, §3/Fig. 12, applied across workers) using the
//! same length statistics that drive the speculation budget, instead of
//! blind round-robin. The LPT cost key folds in per-problem *acceptance*
//! history too (each worker report carries its finished requests'
//! speculation outcomes): a long problem whose drafts are mostly accepted
//! finishes in far fewer target forwards than its raw length suggests, and
//! weighting it by length alone would over-pack it. DAS shrinks per-worker
//! tails, so it compresses the cross-worker makespan too (test below).
//!
//! # Supervision
//!
//! The coordinator is a *supervisor*, not a fan-out barrier. Each worker's
//! shard is split into **chunks** (≈ one full decode batch each) that are
//! dispatched one at a time; only the single in-flight chunk per worker is
//! committed to an engine, everything else sits in coordinator-side queues
//! where it can still be moved:
//!
//! - **Panic isolation + respawn.** Worker loops run every command under
//!   `catch_unwind`; a panic exits the thread, the channels disconnect, and
//!   the coordinator — which never `expect`s on a channel — respawns the
//!   slot. The replacement replays the recorded learner-gain log into a
//!   fresh policy replica (bit-identical to the survivors', since
//!   `policy_update` consumes the replica RNG deterministically),
//!   re-announces the current epoch, and warm-starts its drafter from the
//!   per-worker store when one is configured. The dead worker's unreported
//!   in-flight chunk is re-dispatched exactly once; reports it delivered
//!   before dying are kept (mpsc drains buffered messages before
//!   disconnecting), so no job is lost or duplicated.
//! - **Deadline work-stealing.** The coordinator learns a wall-seconds-per-
//!   predicted-cost rate from completed chunks; a worker whose in-flight
//!   chunk exceeds a generous multiple of its predicted cost is treated as
//!   a straggler and its *queued* chunks migrate to idle workers. At
//!   temperature 0 a spurious steal is harmless — outputs are sharding-
//!   invariant — so the deadline can be aggressive without a correctness
//!   risk.
//! - **Checkpointed preemption.** Stealing only moves *queued* chunks; a
//!   straggler whose queue is already empty keeps the whole step hostage
//!   with its one in-flight chunk. When that chunk blows the same learned
//!   deadline AND a peer sits fully idle, the coordinator arms the worker's
//!   preempt latch: the engine freezes every unfinished request at the next
//!   verification-round boundary into [`RequestCheckpoint`]s, which travel
//!   back on the report channel, hop through the checksummed wire codec,
//!   and re-enter the queues as a first-class resume chunk (stealable,
//!   re-dispatchable like any other). The resuming engine restores each
//!   RNG stream verbatim and replays the drafter scope, so outputs are
//!   bit-identical to an uninterrupted run — preemption, like stealing, is
//!   purely a makespan lever. Resumed requests run with escalated draft
//!   budgets (`spec.resume_budget_boost`): a known straggler on an idle
//!   worker is exactly where deeper speculation is cheapest.
//! - **Deterministic chaos.** A [`FaultPlan`] (config `rollout.fault_plan`)
//!   is shared by every worker incarnation, so injected panics/delays fire
//!   exactly once at fixed seams and chaos runs are reproducible. Every
//!   recovery is visible in [`ParallelStepReport::supervision`].

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use super::engine::{GenJob, RolloutEngine, StepReport};
use super::faults::FaultPlan;
use super::metrics::StepMetrics;
use super::request::RequestCheckpoint;
use crate::config::DasConfig;
use crate::model::sim::{SimModel, SimModelConfig};
use crate::spec::LengthPolicy;
use crate::store::{checksum, Reader, StoreError, Writer};
use crate::tokens::{Epoch, Rollout};

/// Wall-clock floor below which a busy worker is never called a straggler
/// (sub-floor chunks finish faster than stealing could help).
const STEAL_DEADLINE_FLOOR: Duration = Duration::from_millis(50);
/// Deadline = floor + this multiple of the chunk's rate-predicted wall time.
const STEAL_DEADLINE_MULT: f64 = 4.0;
/// Coordinator poll cadence when a sweep made no progress.
const SWEEP_SLEEP: Duration = Duration::from_micros(100);
/// Drop grace before detaching a worker that will not finish (never block
/// teardown forever on a wedged thread).
const SHUTDOWN_GRACE: Duration = Duration::from_secs(1);
/// Backstop against respawn storms inside one step: a slot that cannot even
/// reach its command loop this many times is a programming error (e.g. an
/// engine that panics in its constructor), not a runtime fault to absorb.
const RESPAWN_LIMIT_PER_STEP: u64 = 8;

pub struct DataParallelRollout {
    /// The pool's own config (workers are spawned/respawned from it).
    cfg: DasConfig,
    /// Shared across every worker incarnation: one-shot faults stay
    /// one-shot through respawns.
    faults: Arc<FaultPlan>,
    workers: Vec<WorkerSlot>,
    /// Coordinator-side length statistics feeding the LPT sharder (fed by
    /// every finished rollout; the same survival-statistics predictor the
    /// engines use for speculation budgets). Persisted to
    /// `<store_dir>/coordinator.das` so a resumed pool does not re-learn
    /// its job costs.
    predictor: LengthPolicy,
    /// Ordered learner gains since pool start — the respawn catch-up tape.
    gain_log: Vec<f64>,
    /// Last epoch announced via [`roll_epoch`](Self::roll_epoch);
    /// re-announced to respawned workers.
    current_epoch: Option<Epoch>,
    /// Monotone chunk sequence numbers (delivery-tracking keys).
    next_seq: u64,
    /// EMA of wall seconds per unit of predicted chunk cost, learned from
    /// completed chunks; drives the straggler deadline.
    rate_ema: Option<f64>,
    /// Supervision counters accumulated since the last step report.
    restarts: u64,
    redispatched: u64,
    steals: u64,
    migrated: u64,
    last_saved_epoch: Option<Epoch>,
}

enum Command {
    Chunk {
        jobs: Vec<GenJob>,
        step: u32,
        seq: u64,
    },
    /// Checkpointed requests frozen off another worker: resume them
    /// bit-identically with escalated draft budgets.
    Resume {
        checkpoints: Vec<RequestCheckpoint>,
        step: u32,
        seq: u64,
    },
    RollEpoch(Epoch),
    PolicyUpdate(f64),
    Shutdown,
}

/// A worker's answer to one [`Command::Chunk`], echoing its sequence number
/// so the coordinator can retire exactly that delivery.
struct WorkerReport {
    seq: u64,
    report: StepReport,
}

struct WorkerSlot {
    cmd_tx: Sender<Command>,
    report_rx: Receiver<WorkerReport>,
    thread: Option<JoinHandle<()>>,
    /// Incarnation counter (respawns bump it; thread names carry it).
    generation: u32,
    /// Preempt latch shared with this incarnation's engine: the
    /// coordinator arms it, the engine consumes it at the next
    /// verification-round boundary (the only seam where a queued command
    /// could never reach a worker mid-step).
    preempt: Arc<AtomicBool>,
}

/// What a queued chunk carries: fresh jobs, or checkpoints migrating off a
/// preempted straggler. Resume chunks are first-class — stealable and
/// re-dispatchable exactly like fresh work.
enum ChunkWork {
    Fresh(Vec<GenJob>),
    Resume(Vec<RequestCheckpoint>),
}

/// A coordinator-side unit of dispatch: enough jobs to fill roughly one
/// decode batch. Queued chunks are still the coordinator's to move (steal,
/// re-dispatch); only in-flight chunks are committed to a worker.
struct ChunkTask {
    seq: u64,
    work: ChunkWork,
    /// Sum of the jobs' predicted costs (deadline + load accounting).
    cost: f64,
}

impl ChunkTask {
    /// Dispatchable units inside (jobs or checkpointed requests) — the
    /// denominator for re-dispatch/steal accounting.
    fn len(&self) -> usize {
        match &self.work {
            ChunkWork::Fresh(jobs) => jobs.len(),
            ChunkWork::Resume(cks) => cks.len(),
        }
    }
}

struct InFlight {
    chunk: ChunkTask,
    sent: Instant,
}

/// Merged outcome of one data-parallel step.
#[derive(Debug)]
pub struct ParallelStepReport {
    pub rollouts: Vec<Rollout>,
    /// Slowest worker's generation time — the step latency the learner sees.
    pub makespan: f64,
    /// Sum of worker generation times (device-seconds; utilization proxy).
    pub total_device_time: f64,
    pub per_worker: Vec<StepMetrics>,
    /// Coordinator-side recovery counters for this step: worker restarts,
    /// jobs re-dispatched off dead workers, deadline steals. (Engine-side
    /// recoveries — degraded requests, store failures — arrive through
    /// `per_worker`.)
    pub supervision: StepMetrics,
}

/// Longest-processing-time-first assignment: jobs (by predicted cost) are
/// placed heaviest-first onto the currently least-loaded worker. Returns a
/// worker index per job. Deterministic: cost ties keep submission order,
/// load ties pick the lowest worker index. NaN-safe: `total_cmp` keeps the
/// sort a total order and non-finite costs fall back to a unit load, so one
/// poisoned prediction cannot scramble the schedule.
fn lpt_assignment(costs: &[f64], n_workers: usize) -> Vec<usize> {
    let n = n_workers.max(1);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]).then(a.cmp(&b)));
    let mut assignment = vec![0usize; costs.len()];
    let mut load = vec![0.0f64; n];
    for job in order {
        let mut best = 0usize;
        for w in 1..n {
            if load[w] < load[best] {
                best = w;
            }
        }
        assignment[job] = best;
        // Floor at 1 so zero-cost (or non-finite) predictions still spread
        // across workers instead of piling onto one.
        let c = costs[job];
        load[best] += if c.is_finite() { c.max(1.0) } else { 1.0 };
    }
    assignment
}

/// Magic for the coordinator's persisted predictor state.
const COORD_MAGIC: &str = "das-coord-v1";

fn coordinator_state_path(dir: &Path) -> std::path::PathBuf {
    dir.join("coordinator.das")
}

fn save_coordinator_state(dir: &Path, predictor: &LengthPolicy) -> Result<(), StoreError> {
    std::fs::create_dir_all(dir)?;
    let mut body = Writer::new();
    predictor.save_state(&mut body);
    let mut w = Writer::new();
    w.str(COORD_MAGIC);
    w.u64(checksum(body.as_bytes()));
    w.usize(body.len());
    let mut bytes = w.into_bytes();
    bytes.extend_from_slice(body.as_bytes());
    // Write-then-rename: a crash mid-save leaves the previous state intact.
    let tmp = dir.join("coordinator.das.tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, coordinator_state_path(dir))?;
    Ok(())
}

fn load_coordinator_state(dir: &Path) -> Result<Option<LengthPolicy>, StoreError> {
    let path = coordinator_state_path(dir);
    if !path.exists() {
        return Ok(None);
    }
    let bytes = std::fs::read(&path)?;
    let mut r = Reader::new(&bytes);
    r.expect_str(COORD_MAGIC, "coordinator state magic")?;
    let sum = r.u64()?;
    let len = r.usize()?;
    let body = r.bytes(len)?;
    if checksum(body) != sum {
        return Err(StoreError::Corrupt(
            "coordinator state checksum mismatch".into(),
        ));
    }
    let mut br = Reader::new(body);
    Ok(Some(LengthPolicy::load_state(&mut br)?))
}

/// Read-only integrity check of the `<store_dir>/coordinator.das` sidecar
/// (`das store verify`): magic, checksum, and a full predictor-state parse.
/// Returns the sidecar's byte size, `Ok(None)` when no sidecar exists, and
/// never writes — a corrupted file is reported, not repaired.
pub fn verify_coordinator_sidecar(dir: &Path) -> Result<Option<u64>, StoreError> {
    let path = coordinator_state_path(dir);
    if !path.exists() {
        return Ok(None);
    }
    let size = std::fs::metadata(&path)?.len();
    load_coordinator_state(dir)?;
    Ok(Some(size))
}

/// The migration byte hop: every checkpoint crossing workers goes through
/// the checksummed wire format. An in-memory round trip can only fail on a
/// codec bug; if it ever does, resume from the original rather than lose
/// the request.
fn thaw_checkpoints(cks: &[RequestCheckpoint]) -> Vec<RequestCheckpoint> {
    cks.iter()
        .map(|ck| {
            RequestCheckpoint::from_bytes(&ck.to_bytes()).unwrap_or_else(|e| {
                eprintln!(
                    "das: checkpoint wire round-trip failed ({e}); resuming from \
                     the in-memory copy"
                );
                ck.clone()
            })
        })
        .collect()
}

/// Spawn one worker incarnation. `gains` + `epoch` are the catch-up tape: a
/// respawn replays the learner updates its predecessor had applied (the sim
/// replica consumes its RNG deterministically, so the replayed replica is
/// bit-identical to the survivors') and re-announces the current epoch; the
/// engine warm-starts from the per-worker store when one is configured.
fn spawn_worker(
    cfg: &DasConfig,
    w: usize,
    generation: u32,
    faults: &Arc<FaultPlan>,
    gains: &[f64],
    epoch: Option<Epoch>,
) -> WorkerSlot {
    let mut wcfg = cfg.clone();
    // Worker-local engine seed: shifts request RNG forks, not the policy
    // (the sim replica keeps the shared seed).
    wcfg.seed = cfg.seed ^ ((w as u64 + 1) << 32);
    // Worker-local history store: drafters are worker-local, so each
    // persists (and warm-starts) under its own subdirectory — resuming
    // with the same worker count restores every replica's history.
    if !wcfg.spec.store_dir.is_empty() {
        wcfg.spec.store_dir = format!("{}/worker{w}", wcfg.spec.store_dir);
    }
    // The pool owns the plan: every incarnation gets the SAME shared plan
    // (one-shot faults must not re-fire after a respawn), so keep the
    // engine from parsing a private copy out of the config.
    wcfg.rollout.fault_plan = String::new();
    let model_cfg = SimModelConfig::from_das(cfg);
    let faults = Arc::clone(faults);
    let gains: Vec<f64> = gains.to_vec();
    let (cmd_tx, cmd_rx) = channel::<Command>();
    let (report_tx, report_rx) = channel::<WorkerReport>();
    let preempt = Arc::new(AtomicBool::new(false));
    let latch = Arc::clone(&preempt);
    #[allow(clippy::expect_used)]
    let thread = thread::Builder::new()
        .name(format!("dp-worker-{w}.{generation}"))
        .spawn(move || {
            let mut model = SimModel::new(model_cfg);
            for &g in &gains {
                model.policy_update(g);
            }
            let mut engine = RolloutEngine::new(&wcfg, crate::drafter::from_config(&wcfg));
            engine.set_fault_plan(Arc::clone(&faults));
            engine.set_worker_index(w);
            engine.set_preempt_latch(latch);
            if let Some(e) = epoch {
                engine.roll_epoch(e);
            }
            worker_loop(&mut model, &mut engine, w, &faults, &cmd_rx, &report_tx);
            // Close the store BEFORE the captured channels drop (locals
            // drop first, but make the ordering contract explicit): once
            // the coordinator observes the disconnect, the worker's store
            // directory is safe to reopen.
            drop(engine);
        })
        // audit: allow(panic-path) -- OS refused a thread at startup: unrecoverable, fail loud
        .expect("spawn rollout worker thread");
    WorkerSlot {
        cmd_tx,
        report_rx,
        thread: Some(thread),
        generation,
        preempt,
    }
}

/// The worker's command loop. Every command body runs under `catch_unwind`:
/// a panic (injected or real) breaks the loop instead of unwinding into the
/// runtime, which disconnects the channels — the coordinator's death
/// signal. Shutdown and send-failure (coordinator gone) also break.
fn worker_loop(
    model: &mut SimModel,
    engine: &mut RolloutEngine,
    w: usize,
    faults: &FaultPlan,
    cmd_rx: &Receiver<Command>,
    report_tx: &Sender<WorkerReport>,
) {
    while let Ok(cmd) = cmd_rx.recv() {
        let outcome = catch_unwind(AssertUnwindSafe(|| match cmd {
            Command::Chunk { jobs, step, seq } => {
                if let Some(ms) = faults.delay_ms(w, step) {
                    thread::sleep(Duration::from_millis(ms));
                }
                if faults.should_panic(w, step) {
                    // audit: allow(panic-path) -- this panic IS the injected fault under test
                    panic!("fault plan: panic worker {w} at step {step}");
                }
                let report = engine.generate_step(model, &jobs, step);
                report_tx.send(WorkerReport { seq, report }).is_ok()
            }
            Command::Resume {
                checkpoints,
                step,
                seq,
            } => {
                let report = engine.resume_step(model, &checkpoints, step);
                report_tx.send(WorkerReport { seq, report }).is_ok()
            }
            Command::RollEpoch(e) => {
                engine.roll_epoch(e);
                true
            }
            Command::PolicyUpdate(gain) => {
                model.policy_update(gain);
                true
            }
            Command::Shutdown => false,
        }));
        match outcome {
            Ok(true) => {}
            Ok(false) | Err(_) => break,
        }
    }
}

impl DataParallelRollout {
    /// Build `n_workers` replicas ONCE: each worker thread owns its policy
    /// replica and engine for the lifetime of the pool (respawns replace
    /// single slots, never the pool). Policy replicas share the seed (data
    /// parallelism: same weights everywhere); engines get distinct request
    /// id spaces via the config seed offset so RNG streams never collide.
    pub fn new(cfg: &DasConfig, n_workers: usize) -> Self {
        let faults = Arc::new(FaultPlan::parse(&cfg.rollout.fault_plan).unwrap_or_else(|e| {
            eprintln!("das: invalid rollout.fault_plan ({e}); ignoring");
            FaultPlan::default()
        }));
        let workers = (0..n_workers.max(1))
            .map(|w| spawn_worker(cfg, w, 0, &faults, &[], None))
            .collect();
        // Same thresholds as the worker engines, so the coordinator's LPT
        // keys classify lengths exactly like the engines do. With a store
        // configured, resume the persisted predictor instead of re-learning
        // job costs from scratch.
        let mut predictor = LengthPolicy::from_das(cfg);
        if !cfg.spec.store_dir.is_empty() {
            match load_coordinator_state(Path::new(&cfg.spec.store_dir)) {
                Ok(Some(p)) if p.t_short == predictor.t_short && p.t_long == predictor.t_long => {
                    predictor = p;
                }
                Ok(Some(_)) => eprintln!(
                    "das-store: coordinator state was saved under different length \
                     thresholds; starting the LPT predictor cold"
                ),
                Ok(None) => {}
                Err(e) => eprintln!(
                    "das-store: coordinator state unreadable ({e}); starting the LPT \
                     predictor cold"
                ),
            }
        }
        DataParallelRollout {
            cfg: cfg.clone(),
            faults,
            workers,
            predictor,
            gain_log: Vec::new(),
            current_epoch: None,
            next_seq: 0,
            rate_ema: None,
            restarts: 0,
            redispatched: 0,
            steals: 0,
            migrated: 0,
            last_saved_epoch: None,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// The shared fault plan (chaos harnesses audit it for unfired
    /// directives after a run).
    pub fn fault_plan(&self) -> &FaultPlan {
        &self.faults
    }

    /// Respawn slot `w` after its thread died. The dead thread's store is
    /// already closed: the worker body drops its engine BEFORE its channel
    /// ends disconnect, so observing the disconnect guarantees the
    /// replacement can safely reopen the worker's store directory.
    fn restart_worker(&mut self, w: usize) {
        self.restarts += 1;
        let generation = self.workers[w].generation + 1;
        // The old thread already exited (its channels disconnected), so
        // replacing the slot just drops a finished JoinHandle.
        self.workers[w] = spawn_worker(
            &self.cfg,
            w,
            generation,
            &self.faults,
            &self.gain_log,
            self.current_epoch,
        );
    }

    /// Advance every replica's epoch (window maintenance). Enqueued on the
    /// command channels, so it is ordered with respect to steps. A worker
    /// found dead here is respawned; the replacement re-announces this
    /// epoch itself (it is part of the spawn catch-up tape).
    pub fn roll_epoch(&mut self, epoch: u32) {
        self.current_epoch = Some(epoch);
        for w in 0..self.workers.len() {
            if self.workers[w]
                .cmd_tx
                .send(Command::RollEpoch(epoch))
                .is_err()
            {
                self.restart_worker(w);
            }
        }
        // Epoch boundaries are the predictor's durability points (cheap:
        // a few KB per save).
        if self.last_saved_epoch != Some(epoch) {
            self.last_saved_epoch = Some(epoch);
            self.save_predictor();
        }
    }

    /// Apply the learner update to every policy replica (data parallelism:
    /// identical weights — the sim replicas share seed, so drift stays
    /// bit-identical across workers). Recorded to the gain log FIRST, so a
    /// worker respawned at any later point replays the exact sequence.
    pub fn policy_update(&mut self, gain: f64) {
        self.gain_log.push(gain);
        for w in 0..self.workers.len() {
            if self.workers[w]
                .cmd_tx
                .send(Command::PolicyUpdate(gain))
                .is_err()
            {
                // The replacement replays the full gain log (including this
                // gain) into a fresh replica — applied exactly once.
                self.restart_worker(w);
            }
        }
    }

    fn save_predictor(&mut self) {
        if self.cfg.spec.store_dir.is_empty() {
            return;
        }
        if let Err(e) = save_coordinator_state(Path::new(&self.cfg.spec.store_dir), &self.predictor)
        {
            eprintln!("das-store: coordinator state save failed ({e}); continuing");
        }
    }

    /// Shard `jobs` longest-predicted-first into per-worker chunk queues
    /// and supervise the pool until every chunk is delivered exactly once:
    /// deaths respawn the slot and re-dispatch the unreported chunk,
    /// stragglers lose their queued chunks to idle workers.
    pub fn generate_step(&mut self, jobs: &[GenJob], step: u32) -> ParallelStepReport {
        let n = self.workers.len();
        let costs: Vec<f64> = jobs
            .iter()
            .map(|j| {
                // Sanitize before scheduling: a NaN/∞ cost key must not
                // poison deadlines or load accounting downstream.
                let c = self.predictor.job_cost(j.problem, j.samples);
                if c.is_finite() {
                    c.max(0.0)
                } else {
                    1.0
                }
            })
            .collect();
        let assignment = lpt_assignment(&costs, n);
        // Chunk each worker's shard: ≈ one decode batch per chunk, so the
        // queue keeps work the coordinator can still move. Sequence numbers
        // are assigned here, in deterministic shard order — observations
        // are later folded into the predictor in seq order, which keeps
        // predictor evolution independent of which worker finished first.
        let max_batch = self.cfg.rollout.max_batch.max(1);
        let mut queues: Vec<VecDeque<ChunkTask>> = (0..n).map(|_| VecDeque::new()).collect();
        for (w, queue) in queues.iter_mut().enumerate() {
            let mut chunk_jobs: Vec<GenJob> = Vec::new();
            let mut chunk_cost = 0.0;
            let mut samples = 0usize;
            for (i, job) in jobs.iter().enumerate() {
                if assignment[i] != w {
                    continue;
                }
                samples += job.samples.max(1);
                chunk_cost += costs[i];
                chunk_jobs.push(job.clone());
                if samples >= max_batch {
                    queue.push_back(ChunkTask {
                        seq: self.next_seq,
                        work: ChunkWork::Fresh(std::mem::take(&mut chunk_jobs)),
                        cost: chunk_cost,
                    });
                    self.next_seq += 1;
                    chunk_cost = 0.0;
                    samples = 0;
                }
            }
            if !chunk_jobs.is_empty() {
                queue.push_back(ChunkTask {
                    seq: self.next_seq,
                    work: ChunkWork::Fresh(chunk_jobs),
                    cost: chunk_cost,
                });
                self.next_seq += 1;
            }
        }

        let mut inflight: Vec<Option<InFlight>> = (0..n).map(|_| None).collect();
        let mut completed: Vec<(u64, StepReport, usize)> = Vec::new();
        let restarts_at_entry = self.restarts;

        loop {
            let mut progressed = false;
            for w in 0..n {
                // Dispatch: commit the head of the queue to an idle worker.
                while inflight[w].is_none() {
                    let Some(chunk) = queues[w].pop_front() else { break };
                    let cmd = match &chunk.work {
                        ChunkWork::Fresh(jobs) => Command::Chunk {
                            jobs: jobs.clone(),
                            step,
                            seq: chunk.seq,
                        },
                        ChunkWork::Resume(cks) => Command::Resume {
                            checkpoints: cks.clone(),
                            step,
                            seq: chunk.seq,
                        },
                    };
                    // A latch armed for a chunk this worker already finished
                    // must not leak into the new dispatch.
                    self.workers[w].preempt.store(false, Ordering::Relaxed);
                    if self.workers[w].cmd_tx.send(cmd).is_ok() {
                        inflight[w] = Some(InFlight {
                            chunk,
                            sent: Instant::now(),
                        });
                        progressed = true;
                    } else {
                        // Died between steps: nothing was committed to it.
                        queues[w].push_front(chunk);
                        self.check_respawn_storm(restarts_at_entry);
                        self.restart_worker(w);
                        progressed = true;
                    }
                }
                if inflight[w].is_none() {
                    continue;
                }
                match self.workers[w].report_rx.try_recv() {
                    Ok(WorkerReport { seq, report }) => {
                        if let Some(inf) = inflight[w].take() {
                            debug_assert_eq!(inf.chunk.seq, seq, "reports retire in order");
                            if report.checkpoints.is_empty() {
                                // Learn the wall-per-cost rate for deadlines
                                // (whole chunks only: a preempted chunk's
                                // wall time measures the freeze, not the
                                // work, and would drag the EMA down).
                                let wall = inf.sent.elapsed().as_secs_f64();
                                let rate = wall / inf.chunk.cost.max(1.0);
                                self.rate_ema = Some(match self.rate_ema {
                                    Some(ema) => 0.7 * ema + 0.3 * rate,
                                    None => rate,
                                });
                            } else {
                                // Migration: the frozen requests re-enter
                                // the queues as a first-class resume chunk
                                // on the least-loaded peer — after a hop
                                // through the checksummed wire format, so
                                // the serialized contract is load-bearing
                                // on the hot path, not just in tests.
                                let thawed = thaw_checkpoints(&report.checkpoints);
                                self.migrated += thawed.len() as u64;
                                let cost: f64 = thawed
                                    .iter()
                                    .map(|ck| {
                                        let c = self.predictor.job_cost(ck.problem, 1);
                                        if c.is_finite() {
                                            c.max(0.0)
                                        } else {
                                            1.0
                                        }
                                    })
                                    .sum();
                                let resume_seq = self.next_seq;
                                self.next_seq += 1;
                                let target = least_loaded_queue(&queues, &inflight);
                                queues[target].push_back(ChunkTask {
                                    seq: resume_seq,
                                    work: ChunkWork::Resume(thawed),
                                    cost,
                                });
                            }
                            completed.push((seq, report, w));
                        }
                        progressed = true;
                    }
                    Err(TryRecvError::Empty) => {
                        if self.steal_from_straggler(w, &mut queues, &inflight)
                            || self.maybe_preempt_straggler(w, &queues, &inflight)
                        {
                            progressed = true;
                        }
                    }
                    Err(TryRecvError::Disconnected) => {
                        // Death. Buffered reports were drained by the Ok arm
                        // (mpsc yields queued messages before Disconnected),
                        // so whatever is still in flight was never reported:
                        // re-dispatch it exactly once, onto the least-loaded
                        // live queue.
                        let inf = inflight[w].take();
                        self.check_respawn_storm(restarts_at_entry);
                        self.restart_worker(w);
                        if let Some(inf) = inf {
                            self.redispatched += inf.chunk.len() as u64;
                            let target = least_loaded_queue(&queues, &inflight);
                            queues[target].push_front(inf.chunk);
                        }
                        progressed = true;
                    }
                }
            }
            if inflight.iter().all(Option::is_none) && queues.iter().all(VecDeque::is_empty) {
                break;
            }
            if !progressed {
                thread::sleep(SWEEP_SLEEP);
            }
        }

        // Retire in chunk-creation order: merged metrics, rollouts and
        // predictor updates are then independent of completion order, so
        // respawns/steals never change what the next step's LPT keys see.
        completed.sort_by_key(|&(seq, _, _)| seq);
        let mut per_worker: Vec<StepMetrics> = (0..n).map(|_| StepMetrics::default()).collect();
        let mut rollouts = Vec::new();
        for (_, report, w) in completed {
            for roll in &report.rollouts {
                // Feed the LPT predictor with every observed final length…
                self.predictor.observe(roll.problem, roll.tokens.len());
            }
            // …and with every request's speculation outcome, so the cost
            // key discounts problems that speculate well.
            for &(problem, rounds, accepted) in &report.accept_obs {
                self.predictor.observe_acceptance(problem, rounds, accepted);
            }
            per_worker[w].merge(&report.metrics);
            rollouts.extend(report.rollouts);
        }
        let makespan = per_worker
            .iter()
            .map(|m| m.gen_time)
            .fold(0.0_f64, f64::max);
        let total_device_time: f64 = per_worker.iter().map(|m| m.gen_time).sum();
        // Makespan vs the LPT-with-perfect-lengths lower bound: no schedule
        // can beat perfectly even work (total device time / workers), so the
        // ratio is ≥ 1 and measures makespan left on the table by stragglers.
        let makespan_vs_oracle = if total_device_time > 0.0 {
            makespan / (total_device_time / n as f64).max(f64::EPSILON)
        } else {
            0.0
        };
        let supervision = StepMetrics {
            worker_restarts: std::mem::take(&mut self.restarts),
            jobs_redispatched: std::mem::take(&mut self.redispatched),
            deadline_steals: std::mem::take(&mut self.steals),
            migrated_requests: std::mem::take(&mut self.migrated),
            makespan_vs_oracle,
            ..Default::default()
        };
        ParallelStepReport {
            rollouts,
            makespan,
            total_device_time,
            per_worker,
            supervision,
        }
    }

    /// Deadline policy: when busy worker `w` has exceeded the predicted
    /// wall time of its in-flight chunk by a wide margin, move its queued
    /// chunks to fully idle workers. Only queued work moves — the in-flight
    /// chunk is already committed — so at temperature 0 the outputs cannot
    /// change, only the makespan. Returns true if anything moved.
    fn steal_from_straggler(
        &mut self,
        w: usize,
        queues: &mut [VecDeque<ChunkTask>],
        inflight: &[Option<InFlight>],
    ) -> bool {
        if queues[w].is_empty() {
            return false;
        }
        if !self.deadline_blown(inflight[w].as_ref()) {
            return false;
        }
        let mut moved = false;
        for t in 0..queues.len() {
            if t == w || inflight[t].is_some() || !queues[t].is_empty() {
                continue;
            }
            // Steal from the tail: the head stays next in line on the
            // straggler itself if it ever wakes.
            let Some(chunk) = queues[w].pop_back() else { break };
            self.steals += chunk.len() as u64;
            queues[t].push_back(chunk);
            moved = true;
            if queues[w].is_empty() {
                break;
            }
        }
        moved
    }

    /// The learned straggler deadline: a generous multiple of the in-flight
    /// chunk's rate-predicted wall time. `false` while the rate is unknown
    /// or the worker is idle.
    fn deadline_blown(&self, inf: Option<&InFlight>) -> bool {
        let (Some(rate), Some(inf)) = (self.rate_ema, inf) else {
            return false;
        };
        let predicted = (rate * inf.chunk.cost.max(1.0) * STEAL_DEADLINE_MULT).clamp(0.0, 3600.0);
        let deadline = STEAL_DEADLINE_FLOOR + Duration::from_secs_f64(predicted);
        inf.sent.elapsed() > deadline
    }

    /// Preemption policy — the escalation past work-stealing. Stealing only
    /// helps while the straggler still has QUEUED chunks; once its queue is
    /// empty the in-flight chunk itself holds the step hostage. When that
    /// chunk blows the learned deadline and at least one peer is fully idle
    /// (so the frozen work has somewhere better to go), arm the worker's
    /// preempt latch. The engine freezes at its next verification-round
    /// boundary and the checkpoints come back on the report channel.
    /// Returns true only on the arming transition.
    fn maybe_preempt_straggler(
        &mut self,
        w: usize,
        queues: &[VecDeque<ChunkTask>],
        inflight: &[Option<InFlight>],
    ) -> bool {
        if !queues[w].is_empty() {
            // Queued work exists: stealing is the cheaper remedy.
            return false;
        }
        if !self.deadline_blown(inflight[w].as_ref()) {
            return false;
        }
        let idle_peer_exists = (0..queues.len())
            .any(|t| t != w && inflight[t].is_none() && queues[t].is_empty());
        if !idle_peer_exists {
            return false;
        }
        // swap → true only on the 0→1 transition (re-arming is a no-op).
        !self.workers[w].preempt.swap(true, Ordering::Relaxed)
    }

    fn check_respawn_storm(&self, restarts_at_entry: u64) {
        assert!(
            self.restarts - restarts_at_entry < RESPAWN_LIMIT_PER_STEP,
            "rollout worker respawn storm: {} deaths within one step — the worker \
             cannot reach its command loop (constructor bug?), refusing to livelock",
            self.restarts - restarts_at_entry
        );
    }
}

/// Pick the queue with the least remaining predicted work (queued cost plus
/// the committed in-flight chunk); ties go to the lowest index.
fn least_loaded_queue(queues: &[VecDeque<ChunkTask>], inflight: &[Option<InFlight>]) -> usize {
    let mut best = 0usize;
    let mut best_load = f64::INFINITY;
    for (w, queue) in queues.iter().enumerate() {
        let mut load: f64 = queue.iter().map(|c| c.cost.max(1.0)).sum();
        if let Some(inf) = &inflight[w] {
            load += inf.chunk.cost.max(1.0);
        }
        if load < best_load {
            best_load = load;
            best = w;
        }
    }
    best
}

impl Drop for DataParallelRollout {
    fn drop(&mut self) {
        // Final predictor durability point (covers observations since the
        // last epoch roll).
        self.save_predictor();
        for w in &self.workers {
            let _ = w.cmd_tx.send(Command::Shutdown);
        }
        // Join within a grace window, then detach: a worker that died
        // mid-step joins immediately; a wedged one must not hang teardown.
        // No-fault pools are idle here, so joins are immediate and every
        // store flush has landed before Drop returns.
        let deadline = Instant::now() + SHUTDOWN_GRACE;
        for w in &mut self.workers {
            let Some(t) = w.thread.take() else { continue };
            while !t.is_finished() && Instant::now() < deadline {
                thread::sleep(Duration::from_millis(1));
            }
            if t.is_finished() {
                let _ = t.join();
            }
            // else: handle dropped → thread detached.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DasConfig;

    fn cfg(drafter: &str) -> DasConfig {
        let mut c = DasConfig::default();
        c.model.vocab_size = 128;
        c.workload.n_problems = 12;
        c.workload.len_mu = 3.6;
        c.workload.len_sigma = 0.6;
        c.rollout.max_new_tokens = 160;
        c.rollout.max_batch = 4;
        c.rollout.temperature = 0.0; // greedy: sharding-invariant outputs
        c.spec.drafter = drafter.into();
        c
    }

    fn jobs(n: u32) -> Vec<GenJob> {
        (0..n)
            .map(|p| GenJob {
                problem: p,
                prompt: vec![p + 1, 7],
                samples: 2,
            })
            .collect()
    }

    fn sorted_keys(rollouts: &[Rollout]) -> Vec<(u32, Vec<u32>)> {
        let mut k: Vec<_> = rollouts
            .iter()
            .map(|r| (r.problem, r.tokens.clone()))
            .collect();
        k.sort();
        k
    }

    #[test]
    fn sharding_preserves_greedy_outputs() {
        // The same greedy rollouts regardless of worker count — data
        // parallelism must be semantically invisible.
        let key = |r: &Rollout| (r.problem, r.tokens.clone());
        let mut single = DataParallelRollout::new(&cfg("none"), 1);
        let mut quad = DataParallelRollout::new(&cfg("none"), 4);
        let a = single.generate_step(&jobs(12), 0);
        let b = quad.generate_step(&jobs(12), 0);
        let mut ka: Vec<_> = a.rollouts.iter().map(key).collect();
        let mut kb: Vec<_> = b.rollouts.iter().map(key).collect();
        ka.sort();
        kb.sort();
        assert_eq!(ka, kb);
        assert_eq!(kb.len(), 24);
    }

    #[test]
    fn makespan_is_max_and_device_time_is_sum() {
        let mut dp = DataParallelRollout::new(&cfg("none"), 3);
        let rep = dp.generate_step(&jobs(9), 0);
        let max = rep
            .per_worker
            .iter()
            .map(|m| m.gen_time)
            .fold(0.0_f64, f64::max);
        let sum: f64 = rep.per_worker.iter().map(|m| m.gen_time).sum();
        assert!((rep.makespan - max).abs() < 1e-12);
        assert!((rep.total_device_time - sum).abs() < 1e-12);
        assert!(rep.makespan <= rep.total_device_time);
    }

    #[test]
    fn das_compresses_cross_worker_makespan() {
        // The cluster-level claim: with DAS, the slowest worker finishes
        // sooner once drafters are warm.
        let run = |drafter: &str| -> f64 {
            let mut dp = DataParallelRollout::new(&cfg(drafter), 4);
            let mut makespan = 0.0;
            for step in 0..5 {
                let rep = dp.generate_step(&jobs(12), step);
                if step >= 2 {
                    makespan += rep.makespan;
                }
                dp.policy_update(1.0);
                dp.roll_epoch(step + 1);
            }
            makespan
        };
        let base = run("none");
        let das = run("das");
        assert!(
            das < base,
            "DAS should cut the DP makespan: das={das:.3} base={base:.3}"
        );
    }

    #[test]
    fn uneven_shards_handled() {
        let mut dp = DataParallelRollout::new(&cfg("das"), 4);
        // 5 jobs over 4 workers; one worker gets 2, no worker idles forever.
        let rep = dp.generate_step(&jobs(5), 0);
        assert_eq!(rep.rollouts.len(), 10);
        assert_eq!(rep.per_worker.len(), 4);
    }

    #[test]
    fn pool_survives_many_steps_and_maintenance() {
        // Persistent workers: the same threads serve every step, with epoch
        // rolls and policy updates ordered in between.
        let mut dp = DataParallelRollout::new(&cfg("das"), 2);
        for step in 0..4 {
            let rep = dp.generate_step(&jobs(6), step);
            assert_eq!(rep.rollouts.len(), 12, "step {step}");
            dp.policy_update(1.0);
            dp.roll_epoch(step + 1);
        }
        assert_eq!(dp.n_workers(), 2);
    }

    #[test]
    fn coordinator_predictor_absorbs_acceptance() {
        // The coordinator's LPT predictor must see both halves of the cost
        // key from worker reports: final lengths AND speculation outcomes.
        // No policy updates: step-1 greedy paths replay step-0 rollouts
        // exactly, so at least the stably-assigned problems must accept.
        let mut dp = DataParallelRollout::new(&cfg("das"), 2);
        for step in 0..3 {
            dp.generate_step(&jobs(6), step);
        }
        let with_acceptance: f64 = (0..6).map(|p| dp.predictor.job_cost(p, 2)).sum();
        let length_only: f64 = (0..6)
            .map(|p| {
                dp.predictor.job_cost(p, 2) * (1.0 + dp.predictor.accepted_per_round(p))
            })
            .sum();
        assert!(
            with_acceptance < length_only,
            "after warm steps some problem must speculate and discount its key: {with_acceptance} vs {length_only}"
        );
    }

    #[test]
    fn dp_two_phase_warm_start_restores_worker_history() {
        // Per-worker stores under <dir>/worker<i>: kill the pool after two
        // steps, rebuild it, and the resumed run must report restored
        // history on its first step while producing the same greedy
        // rollouts as a never-killed control pool.
        let dir = crate::store::test_dir("dp-two-phase");
        let mut c = cfg("das");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        c.spec.snapshot_every = 1;
        let mut c_ctrl = c.clone();
        c_ctrl.spec.store_dir = String::new();
        let key = |r: &Rollout| (r.problem, r.tokens.clone());
        let mut control = Vec::new();
        {
            let mut dp = DataParallelRollout::new(&c_ctrl, 2);
            for step in 0..4 {
                dp.roll_epoch(step);
                let rep = dp.generate_step(&jobs(6), step);
                let mut k: Vec<_> = rep.rollouts.iter().map(key).collect();
                k.sort();
                control.push(k);
            }
        }
        {
            let mut dp = DataParallelRollout::new(&c, 2);
            for step in 0..2 {
                dp.roll_epoch(step);
                dp.generate_step(&jobs(6), step);
            }
        } // kill: Drop joins the workers, so all persists have landed
        assert!(
            dir.join("worker0").exists() && dir.join("worker1").exists(),
            "one store per worker"
        );
        let mut dp = DataParallelRollout::new(&c, 2);
        for step in 2..4u32 {
            dp.roll_epoch(step);
            let rep = dp.generate_step(&jobs(6), step);
            if step == 2 {
                let restored: u64 = rep
                    .per_worker
                    .iter()
                    .map(|m| m.index_token_positions)
                    .sum();
                assert!(restored > 0, "first resumed step reports restored history");
            }
            let mut k: Vec<_> = rep.rollouts.iter().map(key).collect();
            k.sort();
            assert_eq!(k, control[step as usize], "resumed rollouts match control");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lpt_beats_round_robin_makespan() {
        // The scheduling argument in isolation: on a skewed cost vector,
        // LPT's worst worker is no worse than round-robin's (and strictly
        // better here).
        let costs = [8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let n = 2;
        let lpt = lpt_assignment(&costs, n);
        let span = |assign: &dyn Fn(usize) -> usize| -> f64 {
            let mut load = vec![0.0; n];
            for (i, &c) in costs.iter().enumerate() {
                load[assign(i)] += c;
            }
            load.iter().fold(0.0_f64, |a, &b| a.max(b))
        };
        let lpt_span = span(&|i| lpt[i]);
        let rr_span = span(&|i| i % n);
        assert!(lpt_span <= rr_span, "lpt={lpt_span} rr={rr_span}");
        assert!((lpt_span - 18.0).abs() < 1e-12, "LPT makespan on this vector is 18");
        assert!((rr_span - 20.0).abs() < 1e-12, "round-robin makespan is 20");
    }

    #[test]
    fn lpt_spreads_equal_costs_evenly() {
        // Cold start (no length history): every job predicts the same cost,
        // and LPT must still balance counts like round-robin would.
        let costs = vec![5.0; 10];
        let assign = lpt_assignment(&costs, 4);
        let mut per_worker = [0usize; 4];
        for &w in &assign {
            per_worker[w] += 1;
        }
        assert_eq!(per_worker.iter().max(), Some(&3));
        assert_eq!(per_worker.iter().min(), Some(&2));
    }

    #[test]
    fn lpt_sanitizes_non_finite_costs() {
        // A poisoned predictor (NaN/∞ cost keys) must neither panic the
        // sort nor pile every job onto one worker: non-finite costs count
        // as unit load, so the spread matches the equal-cost case.
        let costs = vec![f64::NAN; 10];
        let assign = lpt_assignment(&costs, 4);
        let mut per_worker = [0usize; 4];
        for &w in &assign {
            per_worker[w] += 1;
        }
        assert_eq!(per_worker.iter().max(), Some(&3));
        assert_eq!(per_worker.iter().min(), Some(&2));
        // Mixed finite/non-finite stays a total order (no panic) and every
        // job gets exactly one worker.
        let mixed = [f64::INFINITY, 1.0, f64::NAN, 2.0, f64::NEG_INFINITY];
        let assign = lpt_assignment(&mixed, 2);
        assert_eq!(assign.len(), 5);
        assert!(assign.iter().all(|&w| w < 2));
    }

    #[test]
    fn chaos_panics_preserve_greedy_outputs_and_lose_no_jobs() {
        // The chaos-equivalence oracle: kill a different worker at every
        // step boundary and the merged greedy rollouts must stay identical
        // to an undisturbed control pool — no lost jobs, no duplicates —
        // with every recovery visible in the supervision gauges.
        let control = {
            let mut dp = DataParallelRollout::new(&cfg("das"), 3);
            let mut out = Vec::new();
            for step in 0..4 {
                dp.roll_epoch(step);
                let rep = dp.generate_step(&jobs(12), step);
                out.push(sorted_keys(&rep.rollouts));
                dp.policy_update(1.0);
            }
            out
        };
        let mut c = cfg("das");
        c.rollout.fault_plan =
            "panic worker=0 step=1; panic worker=1 step=2; panic worker=2 step=3".into();
        let mut dp = DataParallelRollout::new(&c, 3);
        let mut restarts = 0u64;
        let mut redispatched = 0u64;
        for step in 0..4 {
            dp.roll_epoch(step);
            let rep = dp.generate_step(&jobs(12), step);
            assert_eq!(rep.rollouts.len(), 24, "no lost or duplicated jobs, step {step}");
            assert_eq!(
                sorted_keys(&rep.rollouts),
                control[step as usize],
                "chaos run must match control at step {step}"
            );
            restarts += rep.supervision.worker_restarts;
            redispatched += rep.supervision.jobs_redispatched;
            dp.policy_update(1.0);
        }
        assert_eq!(restarts, 3, "one respawn per injected panic");
        assert!(
            redispatched >= 3,
            "each panic strands an in-flight chunk to re-dispatch: {redispatched}"
        );
        assert!(dp.fault_plan().unfired().is_empty(), "all faults fired");
    }

    #[test]
    fn deadline_policy_steals_queued_jobs_from_a_straggler() {
        // One worker sleeps through its first chunk; the deadline policy
        // must move its queued chunks to the idle peer without changing the
        // greedy outputs.
        let control = {
            let mut dp = DataParallelRollout::new(&cfg("none"), 2);
            sorted_keys(&dp.generate_step(&jobs(8), 0).rollouts)
        };
        let mut c = cfg("none");
        c.rollout.fault_plan = "delay worker=0 step=0 ms=400".into();
        let mut dp = DataParallelRollout::new(&c, 2);
        let rep = dp.generate_step(&jobs(8), 0);
        assert_eq!(sorted_keys(&rep.rollouts), control, "steals never change outputs");
        assert!(
            rep.supervision.deadline_steals > 0,
            "straggler's queued jobs must migrate: {:?}",
            rep.supervision
        );
        assert_eq!(rep.supervision.worker_restarts, 0, "a slow worker is not dead");
    }

    #[test]
    fn dropping_pool_with_panicked_worker_returns_promptly() {
        // Teardown must not block forever on a dead (or wedged) worker:
        // Drop joins within the grace window and detaches otherwise.
        let mut c = cfg("none");
        c.rollout.fault_plan = "panic worker=1 step=0".into();
        let mut dp = DataParallelRollout::new(&c, 2);
        let rep = dp.generate_step(&jobs(6), 0);
        assert_eq!(rep.rollouts.len(), 12);
        assert_eq!(rep.supervision.worker_restarts, 1);
        let t = Instant::now();
        drop(dp);
        assert!(
            t.elapsed() < SHUTDOWN_GRACE + Duration::from_secs(1),
            "drop must return within the shutdown grace window"
        );
    }

    #[test]
    fn forced_preemption_migrates_and_preserves_greedy_outputs() {
        // ISSUE acceptance: a `preempt` directive freezes worker 0's
        // in-flight chunk mid-step; the checkpoints hop the wire codec and
        // resume elsewhere with escalated budgets — and the merged greedy
        // rollouts stay byte-identical to an undisturbed control pool, with
        // the recovery visible in the preemption gauges.
        let control = {
            let mut dp = DataParallelRollout::new(&cfg("das"), 2);
            let mut out = Vec::new();
            for step in 0..3 {
                dp.roll_epoch(step);
                out.push(sorted_keys(&dp.generate_step(&jobs(8), step).rollouts));
                dp.policy_update(1.0);
            }
            out
        };
        let mut c = cfg("das");
        c.rollout.fault_plan = "preempt worker=0 step=1".into();
        let mut dp = DataParallelRollout::new(&c, 2);
        let mut preemptions = 0u64;
        let mut migrated = 0u64;
        for step in 0..3 {
            dp.roll_epoch(step);
            let rep = dp.generate_step(&jobs(8), step);
            assert_eq!(rep.rollouts.len(), 16, "no lost or duplicated requests, step {step}");
            assert_eq!(
                sorted_keys(&rep.rollouts),
                control[step as usize],
                "preempted run must match control at step {step}"
            );
            preemptions += rep.per_worker.iter().map(|m| m.preemptions).sum::<u64>();
            migrated += rep.supervision.migrated_requests;
            if rep.supervision.migrated_requests > 0 {
                let boost = rep
                    .per_worker
                    .iter()
                    .map(|m| m.resume_budget_boost)
                    .fold(0.0_f64, f64::max);
                assert!(
                    (boost - 2.0).abs() < 1e-12,
                    "resumed requests must report the escalated budget: {boost}"
                );
            }
            assert!(
                rep.supervision.makespan_vs_oracle >= 1.0,
                "measured makespan can never beat the oracle bound: {}",
                rep.supervision.makespan_vs_oracle
            );
            dp.policy_update(1.0);
        }
        // ≥, not ==: the deadline policy may legitimately add a preemption
        // on a slow machine (harmless at T=0 — outputs already asserted).
        assert!(preemptions >= 1, "the directive must freeze a chunk: {preemptions}");
        assert!(migrated >= 1, "frozen requests must migrate: {migrated}");
        assert_eq!(dp.fault_plan().preempt_count(), 1);
        assert!(dp.fault_plan().unfired().is_empty(), "all faults fired");
    }

    #[test]
    fn deadline_blown_straggler_with_empty_queue_is_preempted() {
        // The policy path (no fault injection): worker 0 sleeps 500 ms
        // before its only chunk while worker 1 finishes and idles with an
        // empty queue — stealing has nothing to move, so the coordinator
        // must arm the preempt latch and migrate the frozen requests.
        let control = {
            let mut dp = DataParallelRollout::new(&cfg("none"), 2);
            sorted_keys(&dp.generate_step(&jobs(2), 0).rollouts)
        };
        let mut c = cfg("none");
        c.rollout.fault_plan = "delay worker=0 step=0 ms=500".into();
        let mut dp = DataParallelRollout::new(&c, 2);
        let rep = dp.generate_step(&jobs(2), 0);
        assert_eq!(sorted_keys(&rep.rollouts), control, "preemption never changes outputs");
        let preemptions: u64 = rep.per_worker.iter().map(|m| m.preemptions).sum();
        assert!(
            preemptions >= 1 && rep.supervision.migrated_requests >= 1,
            "sleepy straggler must be frozen and its requests migrated: {:?}",
            rep.supervision
        );
        assert_eq!(rep.supervision.worker_restarts, 0, "a slow worker is not dead");
    }

    #[test]
    fn corrupted_coordinator_sidecar_is_reported_and_tolerated() {
        // Satellite: `das store verify` must flag a bad sidecar without
        // panicking, the read-only peek must not repair or delete it, and a
        // rebuilt pool must fall back to a cold predictor.
        let dir = crate::store::test_dir("dp-coord-corrupt");
        let mut c = cfg("das");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        {
            let mut dp = DataParallelRollout::new(&c, 2);
            dp.roll_epoch(0);
            dp.generate_step(&jobs(6), 0);
        } // Drop saves coordinator.das
        let path = dir.join("coordinator.das");
        let ok = verify_coordinator_sidecar(&dir).expect("pristine sidecar verifies");
        assert_eq!(ok, Some(std::fs::metadata(&path).unwrap().len()));
        // Flip one body byte: checksum must catch it.
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(
            verify_coordinator_sidecar(&dir).is_err(),
            "bit flip must be reported"
        );
        assert_eq!(
            std::fs::read(&path).unwrap(),
            bytes,
            "verify is read-only: corrupted sidecar left byte-identical"
        );
        // Truncation (torn write) must be an error too, not a panic.
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(verify_coordinator_sidecar(&dir).is_err(), "torn sidecar reported");
        // A pool built over the corrupt sidecar starts cold but works.
        let mut dp = DataParallelRollout::new(&c, 2);
        let rep = dp.generate_step(&jobs(4), 1);
        assert_eq!(rep.rollouts.len(), 8, "cold-start pool still serves steps");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn coordinator_predictor_state_survives_restart() {
        // The coordinator's LPT predictor persists to
        // <store_dir>/coordinator.das: a rebuilt pool must score every
        // problem exactly like the pool that was dropped.
        let dir = crate::store::test_dir("dp-coord-state");
        let mut c = cfg("das");
        c.spec.store_dir = dir.to_string_lossy().into_owned();
        c.spec.snapshot_every = 1;
        let before: Vec<f64> = {
            let mut dp = DataParallelRollout::new(&c, 2);
            for step in 0..3 {
                dp.roll_epoch(step);
                dp.generate_step(&jobs(12), step);
            }
            (0..12).map(|p| dp.predictor.job_cost(p, 2)).collect()
        }; // Drop saves the final predictor state
        assert!(
            dir.join("coordinator.das").exists(),
            "coordinator state file written"
        );
        let dp = DataParallelRollout::new(&c, 2);
        for (p, want) in before.iter().enumerate() {
            let got = dp.predictor.job_cost(p as u32, 2);
            assert!(
                (got - want).abs() < 1e-12,
                "problem {p}: restored cost {got} != saved cost {want}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Bench: the speculation policy hot paths — the Eq. 9 budget solver,
//! length classification, acceptance-model updates, and verification
//! (Fig. 12's policy axis; these run every round, so they must be far
//! cheaper than one forward pass).

use das::cost::LatencyModel;
use das::spec::budget::{solve, BudgetRequest};
use das::spec::verify::{softmax_with_temperature, verify_sampling};
use das::spec::{AcceptanceEstimator, AcceptanceParams, LengthClass, LengthPolicy};
use das::util::bench::{black_box, Bencher};
use das::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from_u64(7);

    for &n in &[16usize, 64, 256] {
        let reqs: Vec<BudgetRequest> = (0..n)
            .map(|_| BudgetRequest {
                length: 50.0 + rng.next_f64() * 2000.0,
                accept: AcceptanceParams {
                    alpha: 0.2 + rng.next_f64(),
                    k: 0.1 + 0.89 * rng.next_f64(),
                },
            })
            .collect();
        let cost = LatencyModel::paper_like();
        b.bench(&format!("budget_solve_batch{n}"), || {
            black_box(solve(&reqs, &cost));
        });
    }

    let mut policy = LengthPolicy::new(100, 400);
    for p in 0..64u32 {
        for _ in 0..32 {
            policy.observe(p, rng.below(900) + 10);
        }
    }
    let mut p = 0u32;
    b.bench("length_runtime_class", || {
        p = (p + 1) % 64;
        black_box(policy.runtime_class(p, (p as usize * 7) % 500, LengthClass::Medium));
    });

    let mut est = AcceptanceEstimator::default();
    b.bench("acceptance_observe_and_params", || {
        est.observe(8, 5);
        black_box(est.params());
    });

    // Verification of an 8-token draft over a 512 vocab.
    let vocab = 512;
    let logits: Vec<f32> = (0..vocab).map(|_| rng.next_f32() * 8.0).collect();
    let dists: Vec<Vec<f32>> = (0..9)
        .map(|_| softmax_with_temperature(&logits, 0.6))
        .collect();
    let draft: Vec<u32> = (0..8).map(|_| rng.below(vocab) as u32).collect();
    let mut vrng = Rng::seed_from_u64(3);
    b.bench_throughput("verify_sampling_k8_v512", 8, || {
        black_box(verify_sampling(&draft, &dists, &mut vrng));
    });
    b.bench("softmax_t_v512", || {
        black_box(softmax_with_temperature(&logits, 0.6));
    });
    b.summary();
}

//! Bench: suffix-structure operations (Fig. 5's wall-time axis).
//!
//! Query and update costs for the Ukkonen suffix tree, the counting suffix
//! trie (production drafter index) and the suffix array (rebuild-per-insert
//! baseline) across corpus sizes, plus windowed drafting over the fused
//! epoch trie — and a **shared-prefix workload** (same-problem rollouts
//! sharing long boilerplate prefixes, the path-compression target case)
//! with node/byte gauges so the compression ratio lands in the JSON.
//!
//! Flags: `--quick` (small corpus + short windows, for CI),
//! `--json [path]` / env `BENCH_JSON` (write machine-readable results,
//! default `BENCH_suffix.json`).

use das::store::{Reader, Writer};
use das::suffix::{
    SharedPool, SuffixArray, SuffixArrayIndex, SuffixTree, SuffixTrieIndex, WindowedIndex,
};
use das::util::bench::{black_box, Bencher};
use das::util::rng::Rng;

fn corpus(rng: &mut Rng, rollouts: usize, len: usize, alphabet: usize) -> Vec<Vec<u32>> {
    (0..rollouts)
        .map(|_| (0..len).map(|_| rng.below(alphabet) as u32).collect())
        .collect()
}

/// Same-problem rollout groups: every rollout in a group repeats the
/// group's 60-token boilerplate prefix, then diverges into a 40-token tail
/// (the workload shape "Beat the long tail" resamples across epochs).
fn shared_prefix_corpus(rng: &mut Rng, groups: usize, per_group: usize) -> Vec<Vec<u32>> {
    let mut rolls = Vec::with_capacity(groups * per_group);
    for _ in 0..groups {
        let prefix: Vec<u32> = (0..60).map(|_| rng.below(512) as u32).collect();
        for _ in 0..per_group {
            let mut r = prefix.clone();
            r.extend((0..40).map(|_| rng.below(512) as u32));
            rolls.push(r);
        }
    }
    rolls
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let mut rng = Rng::seed_from_u64(42);
    let sizes: &[usize] = if quick { &[10_000] } else { &[10_000, 100_000] };
    for &n_tokens in sizes {
        let rolls = corpus(&mut rng, n_tokens / 100, 100, 512);
        let flat: Vec<u32> = rolls.iter().flatten().copied().collect();

        let mut tree = SuffixTree::new();
        for r in &rolls {
            tree.insert(r);
        }
        let mut trie = SuffixTrieIndex::new(24);
        for r in &rolls {
            trie.insert(r);
        }
        let sa = SuffixArray::build(&flat);
        // Windowed index: same corpus spread over 8 epochs (the fused
        // epoch-ring probes one structure per draft; the old bucket ring
        // walked all 8 bucket tries).
        let mut win = WindowedIndex::new(8, 24);
        for (i, r) in rolls.iter().enumerate() {
            let epoch = (i * 8 / rolls.len()) as u32;
            win.insert(epoch, r);
        }
        // Unbounded ablation (window_all): same fused trie, growable
        // epoch-tag table instead of a bucket ring — draft cost scales with
        // the live epoch span, not with one full walk per epoch.
        let mut win_all = WindowedIndex::new(0, 24);
        for (i, r) in rolls.iter().enumerate() {
            let epoch = (i * 8 / rolls.len()) as u32;
            win_all.insert(epoch, r);
        }

        // Realistic queries: 8-token contexts cut from the corpus.
        let contexts: Vec<Vec<u32>> = (0..128)
            .map(|_| {
                let r = &rolls[rng.below(rolls.len())];
                let s = rng.below(r.len() - 8);
                r[s..s + 8].to_vec()
            })
            .collect();
        let mut i = 0;
        b.bench(&format!("tree_query_{}tok", n_tokens), || {
            let c = &contexts[i % contexts.len()];
            i += 1;
            black_box(tree.draft(c, 8, 16));
        });
        let mut j = 0;
        b.bench(&format!("trie_query_{}tok", n_tokens), || {
            let c = &contexts[j % contexts.len()];
            j += 1;
            black_box(trie.draft_weighted(c, 8, 16));
        });
        let mut k = 0;
        b.bench(&format!("array_query_{}tok", n_tokens), || {
            let c = &contexts[k % contexts.len()];
            k += 1;
            black_box(sa.draft(c, 8, 16));
        });
        let mut l = 0;
        b.bench(&format!("window_draft_{}tok", n_tokens), || {
            let c = &contexts[l % contexts.len()];
            l += 1;
            black_box(win.draft(c, 8, 16));
        });
        let mut la = 0;
        b.bench(&format!("window_all_draft_{}tok", n_tokens), || {
            let c = &contexts[la % contexts.len()];
            la += 1;
            black_box(win_all.draft(c, 8, 16));
        });

        // Update: index one fresh 100-token rollout. Tree/trie are
        // append-only online structures, so we insert into the live index
        // (it grows over iterations; inserts are amortized-constant, which
        // is exactly the property being measured). The array must rebuild,
        // so each iteration pays the full reconstruction.
        let fresh: Vec<u32> = (0..100).map(|_| rng.below(512) as u32).collect();
        let mut tree_live = tree.clone();
        b.bench(&format!("tree_insert100_{}tok", n_tokens), || {
            tree_live.insert(black_box(&fresh));
        });
        let mut trie_live = trie.clone();
        b.bench(&format!("trie_insert100_{}tok", n_tokens), || {
            trie_live.insert(black_box(&fresh));
        });
        let mut win_live = win.clone();
        b.bench(&format!("window_insert100_{}tok", n_tokens), || {
            win_live.insert(7, black_box(&fresh));
        });
        // Array rebuild (the Fig. 5 point): rebuild cost at this corpus
        // size, measured by rebuilding the same-size corpus each iteration.
        let mut idx = SuffixArrayIndex::new();
        idx.insert(&flat[..flat.len() - 101]);
        b.bench(&format!("array_rebuild_insert100_{}tok", n_tokens), || {
            let mut a2 = idx.clone();
            a2.insert(black_box(&fresh));
        });

        // Uniform-corpus size gauges (compression floor: random content).
        b.gauge(&format!("trie_nodes_{}tok", n_tokens), trie.node_count() as f64);
        b.gauge(
            &format!("trie_node_equiv_{}tok", n_tokens),
            trie.token_positions() as f64,
        );
        b.gauge(&format!("trie_bytes_{}tok", n_tokens), trie.approx_bytes() as f64);
        b.gauge(
            &format!("trie_pool_tokens_{}tok", n_tokens),
            trie.pool_stats().live_tokens as f64,
        );

        // -----------------------------------------------------------------
        // Shared-prefix workload: the path-compression target case. Same
        // total token count as the uniform corpus, arranged as same-problem
        // groups repeating 60-token prefixes.
        // -----------------------------------------------------------------
        let groups = (n_tokens / 100 / 20).max(1);
        let shared = shared_prefix_corpus(&mut rng, groups, 20);
        let mut strie = SuffixTrieIndex::new(24);
        for r in &shared {
            strie.insert(r);
        }
        let mut swin = WindowedIndex::new(8, 24);
        for (i, r) in shared.iter().enumerate() {
            let epoch = (i * 8 / shared.len()) as u32;
            swin.insert(epoch, r);
        }
        // The acceptance gauge: ≥2× fewer explicit nodes than the
        // one-node-per-token layout allocated for identical content.
        let ratio = strie.token_positions() as f64 / strie.node_count().max(1) as f64;
        b.gauge(
            &format!("shared_prefix_trie_nodes_{}tok", n_tokens),
            strie.node_count() as f64,
        );
        b.gauge(
            &format!("shared_prefix_trie_node_equiv_{}tok", n_tokens),
            strie.token_positions() as f64,
        );
        b.gauge(
            &format!("shared_prefix_compression_ratio_{}tok", n_tokens),
            ratio,
        );
        b.gauge(
            &format!("shared_prefix_trie_bytes_{}tok", n_tokens),
            strie.approx_bytes() as f64,
        );
        b.gauge(
            &format!("shared_prefix_pool_tokens_{}tok", n_tokens),
            strie.pool_stats().live_tokens as f64,
        );
        assert!(
            ratio >= 2.0,
            "shared-prefix corpus must compress >=2x, got {ratio:.2}x"
        );

        // Insert cost on the shared-prefix shape: one more rollout of an
        // EXISTING group (prefix fully present — the common steady-state
        // insert during RL training).
        let mut fresh_shared = shared[0][..60].to_vec();
        fresh_shared.extend((0..40).map(|_| rng.below(512) as u32));
        let mut strie_live = strie.clone();
        b.bench(&format!("trie_insert_shared_prefix_{}tok", n_tokens), || {
            strie_live.insert(black_box(&fresh_shared));
        });
        let mut swin_live = swin.clone();
        b.bench(&format!("window_insert_shared_prefix_{}tok", n_tokens), || {
            swin_live.insert(7, black_box(&fresh_shared));
        });
        // Draft latency on the shared-prefix index (the no-regression gate:
        // compressed walks must not cost more than the per-token walks did).
        let sctx: Vec<Vec<u32>> = (0..128)
            .map(|_| {
                let r = &shared[rng.below(shared.len())];
                let s = rng.below(r.len() - 8);
                r[s..s + 8].to_vec()
            })
            .collect();
        let mut sq = 0;
        b.bench(&format!("trie_query_shared_prefix_{}tok", n_tokens), || {
            let c = &sctx[sq % sctx.len()];
            sq += 1;
            black_box(strie.draft_weighted(c, 8, 16));
        });
        let mut sw = 0;
        b.bench(&format!("window_draft_shared_prefix_{}tok", n_tokens), || {
            let c = &sctx[sw % sctx.len()];
            sw += 1;
            black_box(swin.draft(c, 8, 16));
        });

        // -----------------------------------------------------------------
        // Persistent store: das-store-v1 serialization cost of the
        // windowed index (the per-snapshot price the engine pays every
        // `spec.snapshot_every` epochs), plus the warm-start load cost and
        // the snapshot's size gauge.
        // -----------------------------------------------------------------
        let snapshot_bytes = {
            let mut w = Writer::new();
            swin.pool().save_state(&mut w);
            swin.save_state(&mut w);
            w.into_bytes()
        };
        b.gauge(
            &format!("store_snapshot_bytes_{}tok", n_tokens),
            snapshot_bytes.len() as f64,
        );
        b.bench(&format!("store_snapshot_save_{}tok", n_tokens), || {
            let mut w = Writer::new();
            swin.pool().save_state(&mut w);
            swin.save_state(&mut w);
            black_box(w.len());
        });
        b.bench(&format!("store_snapshot_load_{}tok", n_tokens), || {
            let mut r = Reader::new(black_box(&snapshot_bytes));
            let (pool, _) = SharedPool::load_state(&mut r).expect("pool loads");
            let mut restored = WindowedIndex::with_pool(8, 24, pool);
            restored.load_state(&mut r).expect("index loads");
            black_box(restored.node_count());
        });
    }
    b.finish("BENCH_suffix.json");
}

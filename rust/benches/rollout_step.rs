//! Bench: end-to-end rollout steps on the simulated policy — the wall-time
//! analog of the paper's per-step generation-time tables (Figs. 10–12).
//!
//! The simulator charges virtual time for model forwards, so the WALL time
//! measured here is the coordinator's own overhead (drafting, batching,
//! verification bookkeeping) — exactly the part DAS adds and the part L3
//! must keep off the critical path. The virtual gen-time ratio between
//! variants is printed alongside.

use das::config::DasConfig;
use das::model::sim::{SimModel, SimModelConfig};
use das::rl::Trainer;
use das::util::bench::Bencher;

fn small(drafter: &str, policy: &str) -> DasConfig {
    let mut c = DasConfig::default();
    c.model.vocab_size = 256;
    c.workload.n_problems = 16;
    c.workload.len_mu = 4.2;
    c.workload.len_sigma = 0.5;
    c.rollout.max_new_tokens = 256;
    c.rollout.max_batch = 16;
    c.rollout.samples_per_problem = 4;
    c.train.problems_per_step = 8;
    c.spec.drafter = drafter.into();
    c.spec.budget_policy = policy.into();
    c
}

fn main() {
    let mut b = Bencher::quick();
    for (name, drafter, policy) in [
        ("baseline_none", "none", "length_aware"),
        ("das_length_aware", "das", "length_aware"),
        ("das_optimal_eq9", "das", "optimal"),
        ("das_unlimited", "das", "unlimited"),
        ("static_ngram", "static", "uniform"),
    ] {
        let cfg = small(drafter, policy);
        let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
        let mut trainer = Trainer::new(cfg);
        // Warm up drafter history.
        for s in 0..3 {
            trainer.step_sim(&mut model, s);
        }
        let mut step = 3u32;
        let mut virt = 0.0;
        let mut iters = 0u32;
        let mut idx_gauges = (0u64, 0u64, 0u64, 0u64);
        b.bench(&format!("rollout_step_{name}"), || {
            let stats = trainer.step_sim(&mut model, step);
            virt += stats.metrics.gen_time;
            idx_gauges = (
                stats.metrics.index_nodes,
                stats.metrics.index_token_positions,
                stats.metrics.index_bytes,
                stats.metrics.pool_bytes,
            );
            step += 1;
            iters += 1;
        });
        println!(
            "    └ virtual gen time: {:.3} s/step (model-clock; lower = better)",
            virt / iters.max(1) as f64
        );
        // End-of-run drafter memory snapshot (zero for non-indexing
        // drafters): compressed nodes vs per-token-equivalent positions.
        b.gauge(&format!("rollout_index_nodes_{name}"), idx_gauges.0 as f64);
        b.gauge(&format!("rollout_index_node_equiv_{name}"), idx_gauges.1 as f64);
        b.gauge(&format!("rollout_index_bytes_{name}"), idx_gauges.2 as f64);
        b.gauge(&format!("rollout_pool_bytes_{name}"), idx_gauges.3 as f64);
    }
    b.finish("BENCH_rollout.json");
}

//! Bench: the REAL hot path — PJRT decode forwards and full engine rounds
//! on the AOT-compiled model (the headline wall-clock numbers for this
//! testbed; skipped when `artifacts/` is absent).

#[cfg(feature = "pjrt")]
use das::config::preset;
#[cfg(feature = "pjrt")]
use das::model::TargetModel;
#[cfg(feature = "pjrt")]
use das::rollout::{GenJob, RolloutEngine};
#[cfg(feature = "pjrt")]
use das::runtime::PjrtModel;
#[cfg(feature = "pjrt")]
use das::util::bench::{black_box, Bencher};

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("e2e_pjrt: built without the pjrt feature (skipping)");
}

#[cfg(feature = "pjrt")]
fn main() {
    if !std::path::Path::new("artifacts/meta.json").exists() {
        eprintln!("e2e_pjrt: artifacts/ missing — run `make artifacts` first (skipping)");
        return;
    }
    let mut b = Bencher::quick();
    let mut model = PjrtModel::load(std::path::Path::new("artifacts")).unwrap();
    let bsz = model.batch_capacity();
    let s = model.meta.max_seq_len;

    // Raw verify forward (the c_base + c_tok·n unit of Eq. 1).
    let tokens: Vec<i32> = (0..bsz * s).map(|i| (i % 60) as i32).collect();
    let q_start: Vec<i32> = vec![8; bsz];
    b.bench("pjrt_decode_forward_b8_s128", || {
        black_box(model.decode_raw(&tokens, &q_start).unwrap());
    });

    // Train step (weights round-trip included).
    let mask: Vec<f32> = (0..bsz * s).map(|i| ((i % s) > 4) as u8 as f32).collect();
    let adv: Vec<f32> = vec![0.1; bsz];
    b.bench("pjrt_train_step", || {
        black_box(model.train_step(&tokens, &mask, &adv, 1e-3).unwrap());
    });

    // Full generation step: baseline vs DAS on the real model.
    for drafter in ["none", "das"] {
        let mut cfg = preset("tiny_pjrt").unwrap();
        cfg.spec.drafter = drafter.into();
        cfg.rollout.max_new_tokens = 24;
        let mut engine = RolloutEngine::new(&cfg, das::drafter::from_config(&cfg));
        let jobs: Vec<GenJob> = (0..4)
            .map(|p| GenJob {
                problem: p,
                prompt: vec![p + 1, 3, 5],
                samples: 2,
            })
            .collect();
        let mut step = 0u32;
        let mut gen_t = 0.0;
        let mut iters = 0;
        b.bench(&format!("pjrt_generate_step_{drafter}"), || {
            let rep = engine.generate_step(&mut model, &jobs, step);
            gen_t += rep.metrics.gen_time;
            step += 1;
            iters += 1;
        });
        println!(
            "    └ decode wall time inside step: {:.3} s (rounds incl. verification)",
            gen_t / iters.max(1) as f64
        );
    }
    b.summary();
}

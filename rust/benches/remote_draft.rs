//! Bench: remote drafting over the `das-draft-rpc-v1` loopback socket —
//! the per-RPC cost the distributed draft service adds on top of the
//! in-process snapshot walk.
//!
//! An in-process [`DraftServer`] binds an OS-chosen loopback port and a
//! [`RemoteSession`] drives it exactly as `RemoteDraftSource` would. The
//! single-RPC draft latency lands in `results` and IS gated by
//! `bench_compare.py`; throughput comparisons (batched frame vs N single
//! frames) and the draft-latency-vs-acceptance budget sweep are gauges —
//! loopback scheduling jitter is machine-dependent and must not trip the
//! regression gate.
//!
//! Flags: `--quick` (short windows, for CI), `--json [path]` / env
//! `BENCH_JSON` (write machine-readable results, default
//! `BENCH_remote_draft.json`).

use std::sync::Arc;
use std::time::Instant;

use das::config::DasConfig;
use das::draftsvc::{DraftReq, DraftServer, RemoteSession, ShardKey};
use das::util::bench::{black_box, Bencher};
use das::util::rng::Rng;

const PROBLEMS: u32 = 16;
const ROLLOUT_LEN: usize = 96;

/// Per-problem token bias so shards carry repeating continuations
/// (drafts actually hit) instead of pure noise.
fn tokens(problem: u32, rng: &mut Rng) -> Vec<u32> {
    (0..ROLLOUT_LEN)
        .map(|_| (problem * 7 + rng.below(48) as u32) % 512)
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let seed_rollouts = if quick { 8 } else { 24 };
    let sweep_draws = if quick { 64usize } else { 512 };

    let cfg = DasConfig::default();
    let mut spec = cfg.spec.clone();
    spec.drafter = "das".into();
    spec.substrate = "window".into();
    spec.scope = "problem".into();

    let server = Arc::new(DraftServer::bind(&spec, None, "127.0.0.1:0").expect("bind loopback"));
    let addr = server.local_addr();
    let handle = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || server.run())
    };
    let session = RemoteSession::new(&addr, 2_000, 2, server.fingerprint());

    // Seed warm history through the wire and keep query contexts around.
    let mut rng = Rng::seed_from_u64(7);
    let mut contexts: Vec<(u32, Vec<u32>)> = Vec::new();
    for p in 0..PROBLEMS {
        for _ in 0..seed_rollouts {
            let toks = tokens(p, &mut rng);
            if contexts.len() < 256 {
                let s = rng.below(ROLLOUT_LEN - 8);
                contexts.push((p, toks[s..s + 8].to_vec()));
            }
            session.absorb(ShardKey::Problem(p), 0, &toks);
        }
    }
    session.roll_epoch(1);

    // Single-RPC draft latency: the gated `results` entry — one context,
    // one frame out, one frame back, snapshot walk server-side.
    let mut i = 0usize;
    b.bench("remote_draft_single_rpc", || {
        let (p, ctx) = &contexts[i % contexts.len()];
        i += 1;
        // snapshot id 0 = the server's live published view.
        black_box(session.draft_one(0, ShardKey::Problem(*p), ctx, 8, 16));
    });

    // Batched frame vs N single frames: same contexts, same answers
    // (transport-only batching), so the delta is pure framing + syscall
    // amortization. Contexts/sec for both shapes land as gauges.
    for &batch in &[4usize, 16] {
        let reqs: Vec<DraftReq> = (0..batch)
            .map(|k| {
                let (p, ctx) = &contexts[k % contexts.len()];
                DraftReq {
                    shard: ShardKey::Problem(*p),
                    context: ctx.clone(),
                    max_match: 8,
                    budget: 16,
                }
            })
            .collect();
        let rounds = sweep_draws / batch.max(1) + 1;
        let start = Instant::now();
        for _ in 0..rounds {
            black_box(session.draft_batch(0, reqs.clone()));
        }
        let batched_cps = (rounds * batch) as f64 / start.elapsed().as_secs_f64();
        let start = Instant::now();
        for _ in 0..rounds {
            for req in &reqs {
                black_box(session.draft_one(
                    0,
                    req.shard,
                    &req.context,
                    req.max_match,
                    req.budget,
                ));
            }
        }
        let single_cps = (rounds * batch) as f64 / start.elapsed().as_secs_f64();
        b.gauge(&format!("remote_draft_batched_contexts_per_sec_{batch}"), batched_cps);
        b.gauge(&format!("remote_draft_single_contexts_per_sec_{batch}"), single_cps);
        if single_cps > 0.0 {
            b.gauge(
                &format!("remote_draft_batch_speedup_{batch}"),
                batched_cps / single_cps,
            );
        }
    }

    // Budget sweep: latency vs draft yield. Bigger budgets walk deeper
    // server-side and ship longer bodies back — the paper's
    // draft-length-vs-acceptance tradeoff, measured at the transport.
    for &budget in &[4usize, 8, 16, 32] {
        let start = Instant::now();
        let mut drafted = 0u64;
        for d in 0..sweep_draws {
            let (p, ctx) = &contexts[d % contexts.len()];
            let draft = session.draft_one(0, ShardKey::Problem(*p), ctx, 8, budget);
            drafted += draft.tokens.len() as u64;
        }
        let secs = start.elapsed().as_secs_f64();
        b.gauge(
            &format!("remote_draft_rpc_latency_us_budget_{budget}"),
            secs / sweep_draws as f64 * 1e6,
        );
        b.gauge(
            &format!("remote_draft_tokens_per_rpc_budget_{budget}"),
            drafted as f64 / sweep_draws as f64,
        );
    }

    let stats = session.drain_stats();
    assert_eq!(stats.degraded, 0, "bench ran against a healthy server");
    b.gauge("remote_draft_total_round_trips", stats.round_trips as f64);

    server.stop();
    handle.join().expect("server thread");
    b.finish("BENCH_remote_draft.json");
}

//! Bench: concurrent snapshot drafting — reader scaling against a live
//! writer (the PR's lock-free read-path claim, measured).
//!
//! One writer thread absorbs rollouts and republishes [`DrafterSnapshot`]s
//! while 1/2/4/8 reader threads draft continuously off the latest publish.
//! Readers never touch a lock on the draft itself — they refresh their
//! `Arc` handle from a shared cell every few hundred draws and otherwise
//! walk immutable chunk tables. Reads-per-second lands in the JSON as
//! gauges (`bench_compare.py` diffs only `results`, so machine-dependent
//! scaling never trips the regression gate); the single-thread snapshot
//! draft latency is a `results` entry and IS gated.
//!
//! Flags: `--quick` (short windows, for CI), `--json [path]` / env
//! `BENCH_JSON` (write machine-readable results, default
//! `BENCH_concurrent_draft.json`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use das::config::DasConfig;
use das::drafter::{from_config, Drafter, DrafterSnapshot};
use das::tokens::Rollout;
use das::util::bench::{black_box, Bencher};
use das::util::rng::Rng;

const PROBLEMS: u32 = 32;
const ROLLOUT_LEN: usize = 96;

fn rollout(problem: u32, epoch: u32, rng: &mut Rng) -> Rollout {
    // Per-problem token bias so shards carry repeating continuations
    // (drafts actually hit) instead of pure noise.
    let tokens = (0..ROLLOUT_LEN)
        .map(|_| (problem * 7 + rng.below(48) as u32) % 512)
        .collect();
    Rollout {
        problem,
        epoch,
        step: 0,
        tokens,
        reward: 0.0,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    // Wall window for each reader-count measurement (long enough that
    // thread startup is noise, short enough for CI).
    let window_secs = if quick { 0.15 } else { 0.6 };
    let seed_rollouts = if quick { 8 } else { 24 };

    let mut cfg = DasConfig::default();
    cfg.spec.drafter = "das".into();
    cfg.spec.substrate = "window".into();
    cfg.spec.scope = "problem".into();
    let mut drafter = from_config(&cfg);

    // Seed warm history and keep the material around for query contexts.
    let mut rng = Rng::seed_from_u64(7);
    let mut contexts: Vec<Vec<u32>> = Vec::new();
    for p in 0..PROBLEMS {
        for _ in 0..seed_rollouts {
            let r = rollout(p, 0, &mut rng);
            if contexts.len() < 256 {
                let s = rng.below(ROLLOUT_LEN - 8);
                contexts.push(r.tokens[s..s + 8].to_vec());
            }
            drafter.observe_rollout(&r);
        }
    }
    drafter.roll_epoch(1);

    // Single-thread snapshot draft latency: the gated `results` entry (the
    // hot path a reader thread runs per draw).
    let snap = drafter.snapshot().expect("das drafter publishes snapshots");
    let mut i = 0usize;
    b.bench("snapshot_draft_single", || {
        let c = &contexts[i % contexts.len()];
        i += 1;
        black_box(snap.draft(1, (i % PROBLEMS as usize) as u32, c, 16));
    });
    drop(snap);

    // Reader scaling × one concurrent writer. The writer absorbs fresh
    // rollouts, rolls epochs, and republishes; readers draft off whatever
    // publish their handle points at, refreshing it every 256 draws.
    let mut single_rps = 0.0f64;
    let mut last_rps = 0.0f64;
    for &readers in &[1usize, 2, 4, 8] {
        let cell: Mutex<Arc<DrafterSnapshot>> =
            Mutex::new(drafter.snapshot().expect("publish"));
        let stop = AtomicBool::new(false);
        let reads = AtomicU64::new(0);
        let mut absorbs = 0u64;
        let start = Instant::now();
        std::thread::scope(|s| {
            for r in 0..readers {
                let cell = &cell;
                let stop = &stop;
                let reads = &reads;
                let contexts = &contexts;
                s.spawn(move || {
                    let mut snap = cell.lock().unwrap().clone();
                    let mut n = 0u64;
                    let mut i = r * 17;
                    while !stop.load(Ordering::Relaxed) {
                        if n % 256 == 255 {
                            snap = cell.lock().unwrap().clone();
                        }
                        let c = &contexts[i % contexts.len()];
                        i += 1;
                        black_box(snap.draft(
                            r as u64,
                            (i % PROBLEMS as usize) as u32,
                            c,
                            16,
                        ));
                        n += 1;
                    }
                    reads.fetch_add(n, Ordering::Relaxed);
                });
            }
            // Writer half: single-threaded mutation + republish, exactly
            // the engine's step-loop role.
            let mut wrng = Rng::seed_from_u64(99 + readers as u64);
            let mut epoch = 1u32;
            while start.elapsed().as_secs_f64() < window_secs {
                let p = (absorbs % PROBLEMS as u64) as u32;
                drafter.observe_rollout(&rollout(p, epoch, &mut wrng));
                absorbs += 1;
                if absorbs % 64 == 0 {
                    epoch += 1;
                    drafter.roll_epoch(epoch);
                }
                if let Some(s2) = drafter.snapshot() {
                    *cell.lock().unwrap() = s2;
                }
            }
            stop.store(true, Ordering::Relaxed);
        });
        let secs = start.elapsed().as_secs_f64();
        let rps = reads.load(Ordering::Relaxed) as f64 / secs;
        if readers == 1 {
            single_rps = rps;
        }
        last_rps = rps;
        b.gauge(&format!("concurrent_draft_reads_per_sec_{readers}r"), rps);
        b.gauge(
            &format!("concurrent_draft_writer_absorbs_per_sec_{readers}r"),
            absorbs as f64 / secs,
        );
    }
    // Scaling summary (8 readers vs 1, writer live in both): informational
    // — hardware-dependent (CI runners may expose 2 cores), so a gauge
    // rather than an assert. On ≥8-core machines this should be ≥4×.
    if single_rps > 0.0 {
        b.gauge("concurrent_draft_scaling_8r_over_1r", last_rps / single_rps);
    }
    b.finish("BENCH_concurrent_draft.json");
}

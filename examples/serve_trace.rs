//! Rollout-only serving over a trace workload: exercises the continuous
//! batcher + drafter without any training loop — the shape of a standalone
//! "rollout worker" process in a disaggregated RL system.
//!
//! Prints per-step throughput and the effective-batch trace (the Fig. 1
//! collapse is visible directly in the output).
//!
//! Run: `cargo run --release --example serve_trace`

use das::config::preset;
use das::drafter;
use das::model::sim::{SimModel, SimModelConfig};
use das::model::TargetModel;
use das::rollout::{GenJob, RolloutEngine};
use das::util::rng::Rng;

fn sparkline(trace: &[u32]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = trace.iter().copied().max().unwrap_or(1).max(1) as f64;
    // Downsample to ~60 chars.
    let stride = (trace.len() / 60).max(1);
    trace
        .iter()
        .step_by(stride)
        .map(|&v| BARS[((v as f64 / max) * 7.0).round() as usize])
        .collect()
}

fn main() -> anyhow::Result<()> {
    let mut cfg = preset("trace").unwrap();
    cfg.rollout.max_batch = 32;
    cfg.rollout.max_new_tokens = 768;
    cfg.workload.n_problems = 64;
    let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
    let mut engine = RolloutEngine::new(&cfg, drafter::from_config(&cfg));
    let mut rng = Rng::seed_from_u64(cfg.seed);

    println!("serving trace batches (batch cap {}):", cfg.rollout.max_batch);
    for step in 0..6u32 {
        engine.roll_epoch(step);
        // A trace batch: random subset of problems, 2 samples each.
        let jobs: Vec<GenJob> = (0..16)
            .map(|_| {
                let p = rng.below(cfg.workload.n_problems) as u32;
                GenJob {
                    problem: p,
                    prompt: vec![p % 60, (p / 7) % 60, 11],
                    samples: 2,
                }
            })
            .collect();
        let rep = engine.generate_step(&mut model, &jobs, step);
        let m = &rep.metrics;
        println!(
            "step {step}: {:>6} toks in {:>6.2}s model-time ({:>6.0} tok/s) \
             accept {:>4.1}%  eff-batch {}",
            m.generated,
            m.gen_time,
            m.generated as f64 / m.gen_time.max(1e-9),
            100.0 * m.accept_rate(),
            sparkline(&m.eff_batch),
        );
        model.policy_update(0.5);
    }
    println!(
        "\nThe sparkline is the Fig. 1 story: full parallelism, then collapse \
         to a straggler tail. With the DAS drafter warm, the tail shortens."
    );
    Ok(())
}

//! Quickstart: the DAS public API in ~60 lines.
//!
//! Builds a rollout engine with the adaptive suffix drafter, generates a
//! few batches of rollouts against the simulated policy, and prints what
//! speculation is doing. No artifacts required.
//!
//! Run: `cargo run --release --example quickstart`

use das::config::DasConfig;
use das::drafter;
use das::model::sim::{SimModel, SimModelConfig};
use das::rollout::{GenJob, RolloutEngine};

fn main() {
    // 1. Configure. Presets mirror the paper's setups; everything is a
    //    plain struct you can override.
    let mut cfg = DasConfig::default(); // math_rl preset
    cfg.workload.n_problems = 8;
    cfg.rollout.max_new_tokens = 256;
    cfg.rollout.max_batch = 8;
    cfg.workload.len_mu = 4.5;

    // 2. A target model. `SimModel` is the calibrated synthetic policy;
    //    swap in `das::runtime::PjrtModel::load("artifacts")` for the real
    //    AOT-compiled transformer.
    let mut model = SimModel::new(SimModelConfig::from_das(&cfg));

    // 3. The engine: continuous batcher + drafter + length-aware budgets +
    //    lossless verification.
    let mut engine = RolloutEngine::new(&cfg, drafter::from_config(&cfg));

    let jobs: Vec<GenJob> = (0..8)
        .map(|p| GenJob {
            problem: p,
            prompt: vec![p + 1, 17, 3],
            samples: 4,
        })
        .collect();

    println!("step | gen_time | rounds | tok/pass | accept | drafts");
    for step in 0..6 {
        engine.roll_epoch(step); // window maintenance
        let report = engine.generate_step(&mut model, &jobs, step);
        let m = &report.metrics;
        println!(
            "{:>4} | {:>7.3}s | {:>6} | {:>8.2} | {:>5.1}% | {} proposed / {} accepted",
            step,
            m.gen_time,
            m.rounds,
            m.tokens_per_pass(),
            100.0 * m.accept_rate(),
            m.proposed,
            m.accepted,
        );
        // The policy updates between steps (this is what breaks static
        // drafters — and what the sliding window absorbs).
        model.policy_update(1.0);
    }
    println!(
        "\nAfter warmup the drafter retrieves most continuations from recent \
         rollouts:\ntokens-per-forward-pass climbs well above 1.0 while outputs \
         remain exactly the target model's (lossless verification)."
    );
}

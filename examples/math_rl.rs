//! END-TO-END driver (EXPERIMENTS.md §E2E): GRPO-train the REAL
//! AOT-compiled transformer on a verifiable math task, with rollouts served
//! by the full DAS stack over PJRT — and compare against the no-speculation
//! baseline.
//!
//! This is the "all layers compose" proof: Pallas kernels (L1) → JAX model
//! lowered to HLO (L2) → Rust coordinator decoding speculatively and
//! training through the `train_step` executable (L3). Python is not running
//! anywhere in this binary.
//!
//! Requires: `make artifacts`. Run:
//! `cargo run --release --example math_rl [-- steps]`

use std::path::Path;

use das::config::preset;
use das::rl::Trainer;
use das::runtime::PjrtModel;
use das::telemetry::Table;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    anyhow::ensure!(
        Path::new("artifacts/meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    let mut table = Table::new(
        "math_rl_e2e",
        &[
            "step", "variant", "reward", "loss", "gen_wall_s", "rounds", "tok_per_pass",
            "accept_rate",
        ],
    );
    let mut totals = Vec::new();
    for variant in ["none", "das"] {
        let mut cfg = preset("tiny_pjrt").unwrap();
        cfg.spec.drafter = variant.into();
        cfg.rollout.temperature = 0.9;
        println!("\n=== variant: {variant} ===");
        let mut model = PjrtModel::load(Path::new("artifacts"))?;
        let rep = model.calibrate(3)?;
        println!(
            "calibrated: t_fwd = {:.4}s + {:.2}µs/tok (R²={:.3})",
            rep.model.c_base,
            rep.model.c_tok * 1e6,
            rep.r_squared
        );
        let mut trainer = Trainer::new(cfg);
        let mut gen_total = 0.0;
        let mut reward_curve = Vec::new();
        for step in 0..steps {
            let s = trainer.step_pjrt(&mut model, step as u32);
            gen_total += s.metrics.gen_time;
            reward_curve.push(s.reward);
            if step % 5 == 0 || step + 1 == steps {
                println!(
                    "step {:>3}  reward {:.3}  loss {:+.4}  gen {:.3}s  \
                     tok/pass {:.2}  accept {:.0}%",
                    step,
                    s.reward,
                    s.loss,
                    s.metrics.gen_time,
                    s.metrics.tokens_per_pass(),
                    100.0 * s.metrics.accept_rate()
                );
            }
            table.row(vec![
                step.to_string(),
                variant.to_string(),
                format!("{:.4}", s.reward),
                format!("{:.4}", s.loss),
                format!("{:.4}", s.metrics.gen_time),
                s.metrics.rounds.to_string(),
                format!("{:.3}", s.metrics.tokens_per_pass()),
                format!("{:.3}", s.metrics.accept_rate()),
            ]);
        }
        let k = (steps / 4).max(1);
        let late_reward: f64 = reward_curve[steps - k..].iter().sum::<f64>() / k as f64;
        let early_reward: f64 = reward_curve[..k].iter().sum::<f64>() / k as f64;
        println!(
            "total generation wall time: {gen_total:.2}s; reward {early_reward:.3} → {late_reward:.3}"
        );
        totals.push((variant, gen_total, early_reward, late_reward));
    }
    let path = table.write_csv(Path::new("results"))?;
    println!("\nwrote {}", path.display());
    let (_, t_base, _, r_base) = totals[0];
    let (_, t_das, _, r_das) = totals[1];
    println!(
        "\nE2E summary (real PJRT model, {steps} steps):\n\
         rollout wall time  baseline {t_base:.2}s → DAS {t_das:.2}s  ({:+.0}%)\n\
         late-training reward  baseline {r_base:.3} vs DAS {r_das:.3}\n\
         (paper Fig. 10: >50% rollout-time cut at 7B/H100 scale; at this \
         tiny scale c_base dominates and the achievable cut tracks the \
         acceptance rate)",
        100.0 * (t_das / t_base - 1.0),
    );
    Ok(())
}

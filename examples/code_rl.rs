//! Code-RL scenario (paper §5.2 analog): DeepCoder-style training where the
//! reward is the unit-test pass fraction of generated token-programs,
//! executed on the stack VM — run at paper-shaped scale on the simulated
//! policy with the calibrated virtual clock.
//!
//! Compares the VeRL-baseline, DAS, and DAS-with-unlimited-budget (the
//! Fig. 12 ablation) in one run.
//!
//! Run: `cargo run --release --example code_rl [-- steps]`

use das::config::preset;
use das::model::sim::{SimModel, SimModelConfig};
use das::rl::Trainer;
use das::telemetry::Table;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let variants: [(&str, &str, &str); 3] = [
        ("baseline", "none", "length_aware"),
        ("das", "das", "length_aware"),
        ("das_unlimited", "das", "unlimited"),
    ];
    let mut table = Table::new(
        "code_rl_e2e",
        &["step", "variant", "reward", "gen_time_s", "accept_rate"],
    );
    let mut summary = Vec::new();
    for (name, drafter, policy) in variants {
        let mut cfg = preset("code_rl").unwrap();
        cfg.spec.drafter = drafter.into();
        cfg.spec.budget_policy = policy.into();
        cfg.workload.n_problems = 16;
        cfg.workload.len_mu = 4.6; // visible reward dynamics within a short demo
        cfg.rollout.max_new_tokens = 768;
        println!("\n=== {name} ===");
        let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
        let mut trainer = Trainer::new(cfg);
        let mut total = 0.0;
        let mut last_reward = 0.0;
        for step in 0..steps {
            let s = trainer.step_sim(&mut model, step as u32);
            total += s.metrics.gen_time;
            last_reward = s.reward;
            if step % 4 == 0 || step + 1 == steps {
                println!(
                    "step {:>3}  unit-test reward {:.3}  gen {:.3}s  accept {:.0}%",
                    step,
                    s.reward,
                    s.metrics.gen_time,
                    100.0 * s.metrics.accept_rate()
                );
            }
            table.row(vec![
                step.to_string(),
                name.to_string(),
                format!("{:.4}", s.reward),
                format!("{:.4}", s.metrics.gen_time),
                format!("{:.3}", s.metrics.accept_rate()),
            ]);
        }
        println!("total rollout time: {total:.2}s (model clock)");
        summary.push((name, total, last_reward));
    }
    let path = table.write_csv(std::path::Path::new("results"))?;
    println!("\nwrote {}", path.display());
    let base = summary[0].1;
    println!("\nSummary ({} steps):", steps);
    for (name, total, reward) in &summary {
        println!(
            "  {name:<14} rollout {total:>7.2}s  ({:+5.1}% vs baseline)  final reward {reward:.3}",
            100.0 * (total / base - 1.0)
        );
    }
    println!(
        "(paper: DAS ≈ −25% on code; unlimited budget gives back ~15% of the gain)"
    );
    Ok(())
}

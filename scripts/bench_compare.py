#!/usr/bin/env python3
"""Compare two das-bench-v1 JSON files and fail on perf regressions.

Usage: bench_compare.py [--require-baseline] BASELINE.json FRESH.json [max_regression]

For every named bench present in BOTH files, compare fresh median_ns
against the baseline's. Exit 1 if any bench regressed by more than
``max_regression`` (default 0.25, i.e. fresh > 1.25x baseline). Benches
present in only one file are reported but never fail the run (renames and
new benches are not regressions).

An empty baseline passes with a loud warning by default (the historical
committed-JSON seed state), or fails outright under ``--require-baseline``
— the mode CI uses now that the baseline is regenerated from the merge
base on every run, where "empty" can only mean the gate is broken.
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "das-bench-v1":
        sys.exit(f"{path}: not a das-bench-v1 file (schema={doc.get('schema')!r})")
    return {r["name"]: r for r in doc.get("results", []) if "name" in r}


def main():
    require_baseline = "--require-baseline" in sys.argv[1:]
    args = [a for a in sys.argv[1:] if a != "--require-baseline"]
    if len(args) < 2:
        sys.exit(__doc__)
    base_path, fresh_path = args[0], args[1]
    max_regression = float(args[2]) if len(args) > 2 else 0.25
    base = load(base_path)
    fresh = load(fresh_path)

    if not base:
        msg = (
            f"baseline {base_path} has empty 'results' — the perf gate "
            f"cannot detect regressions against it"
        )
        if require_baseline:
            # CI regenerates the baseline from the merge base, so an empty
            # one means the gate itself is broken — fail, don't warn.
            sys.exit(f"FAIL: {msg} (--require-baseline)")
        # Legacy committed-JSON mode: pass, but LOUDLY, so a quietly-stale
        # baseline can't masquerade as a green perf check.
        print(f"WARNING: {msg}", file=sys.stderr)
        if os.environ.get("GITHUB_ACTIONS") == "true":
            # Workflow-command annotation: shows on the run summary and the
            # PR checks tab, not just buried in the step log.
            print(f"::warning title=bench_compare: empty baseline::{msg}")
        return

    regressions = []
    print(f"{'bench':<44} {'base med':>12} {'fresh med':>12} {'ratio':>8}")
    for name in sorted(set(base) | set(fresh)):
        b, f = base.get(name), fresh.get(name)
        if b is None:
            print(f"{name:<44} {'-':>12} {f['median_ns']:>12.0f} {'new':>8}")
            continue
        if f is None:
            print(f"{name:<44} {b['median_ns']:>12.0f} {'-':>12} {'gone':>8}")
            continue
        base_med, fresh_med = b["median_ns"], f["median_ns"]
        ratio = fresh_med / base_med if base_med > 0 else float("inf")
        flag = " <-- REGRESSION" if ratio > 1.0 + max_regression else ""
        print(f"{name:<44} {base_med:>12.0f} {fresh_med:>12.0f} {ratio:>8.2f}{flag}")
        if ratio > 1.0 + max_regression:
            regressions.append((name, ratio))

    if regressions:
        worst = ", ".join(f"{n} ({r:.2f}x)" for n, r in regressions)
        sys.exit(f"FAIL: {len(regressions)} bench(es) regressed >" f"{max_regression:.0%}: {worst}")
    print(f"OK: no bench regressed more than {max_regression:.0%}")


if __name__ == "__main__":
    main()

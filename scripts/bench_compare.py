#!/usr/bin/env python3
"""Compare two das-bench-v1 JSON files and fail on perf regressions.

Usage: bench_compare.py BASELINE.json FRESH.json [max_regression]

For every named bench present in BOTH files, compare fresh median_ns
against the baseline's. Exit 1 if any bench regressed by more than
``max_regression`` (default 0.25, i.e. fresh > 1.25x baseline). Benches
present in only one file are reported but never fail the run (renames and
new benches are not regressions). An empty baseline (the seed state before
CI first refreshes the committed JSON) passes trivially.

This is the first brick of the ROADMAP perf-trajectory gate: CI snapshots
the committed BENCH_*.json before re-running the benches, then diffs.
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "das-bench-v1":
        sys.exit(f"{path}: not a das-bench-v1 file (schema={doc.get('schema')!r})")
    return {r["name"]: r for r in doc.get("results", []) if "name" in r}


def main():
    if len(sys.argv) < 3:
        sys.exit(__doc__)
    base_path, fresh_path = sys.argv[1], sys.argv[2]
    max_regression = float(sys.argv[3]) if len(sys.argv) > 3 else 0.25
    base = load(base_path)
    fresh = load(fresh_path)

    if not base:
        # Pass, but LOUDLY: an empty baseline means the perf gate is not
        # actually gating anything. CI surfaces stderr, so a quietly-stale
        # committed baseline can't masquerade as a green perf check.
        msg = (
            f"baseline {base_path} has empty 'results' — the perf gate "
            f"cannot detect regressions until a populated baseline is "
            f"committed (run the bench with --json {base_path} on a quiet "
            f"machine and commit the refreshed file)"
        )
        print(f"WARNING: {msg}", file=sys.stderr)
        if os.environ.get("GITHUB_ACTIONS") == "true":
            # Workflow-command annotation: shows on the run summary and the
            # PR checks tab, not just buried in the step log.
            print(f"::warning title=bench_compare: empty baseline::{msg}")
        return

    regressions = []
    print(f"{'bench':<44} {'base med':>12} {'fresh med':>12} {'ratio':>8}")
    for name in sorted(set(base) | set(fresh)):
        b, f = base.get(name), fresh.get(name)
        if b is None:
            print(f"{name:<44} {'-':>12} {f['median_ns']:>12.0f} {'new':>8}")
            continue
        if f is None:
            print(f"{name:<44} {b['median_ns']:>12.0f} {'-':>12} {'gone':>8}")
            continue
        base_med, fresh_med = b["median_ns"], f["median_ns"]
        ratio = fresh_med / base_med if base_med > 0 else float("inf")
        flag = " <-- REGRESSION" if ratio > 1.0 + max_regression else ""
        print(f"{name:<44} {base_med:>12.0f} {fresh_med:>12.0f} {ratio:>8.2f}{flag}")
        if ratio > 1.0 + max_regression:
            regressions.append((name, ratio))

    if regressions:
        worst = ", ".join(f"{n} ({r:.2f}x)" for n, r in regressions)
        sys.exit(f"FAIL: {len(regressions)} bench(es) regressed >" f"{max_regression:.0%}: {worst}")
    print(f"OK: no bench regressed more than {max_regression:.0%}")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Compare two das-bench-v1 JSON files and fail on perf regressions.

Usage: bench_compare.py [--require-baseline] BASELINE.json FRESH.json [max_regression]

For every named bench present in BOTH files, compare fresh median_ns
against the baseline's. Exit 1 if any bench regressed by more than
``max_regression`` (default 0.25, i.e. fresh > 1.25x baseline). Benches
present in only one file are reported but never fail the run (renames and
new benches are not regressions). Benches with a missing or zero median on
either side (``--quick`` runs can produce sub-resolution timings) are
reported as ``n/a`` and never fail the run — a 0ns median is a measurement
artifact, not a 0ns bench.

An empty baseline passes with a loud warning by default (the historical
committed-JSON seed state), or fails outright under ``--require-baseline``
— the mode CI uses now that the baseline is regenerated from the merge
base on every run, where "empty" can only mean the gate is broken.

When ``GITHUB_STEP_SUMMARY`` is set, a per-bench delta table is appended to
it so the comparison shows on the workflow run page without digging
through step logs.
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "das-bench-v1":
        sys.exit(f"{path}: not a das-bench-v1 file (schema={doc.get('schema')!r})")
    return {r["name"]: r for r in doc.get("results", []) if "name" in r}


def median_of(result):
    """A usable median or None: --quick runs can emit missing/zero/negative
    medians (timer resolution), which must not fake an infinite ratio."""
    if result is None:
        return None
    med = result.get("median_ns")
    if not isinstance(med, (int, float)) or med <= 0:
        return None
    return float(med)


def fmt_ns(med):
    return f"{med:.0f}" if med is not None else "-"


def write_step_summary(rows, max_regression, n_regressions):
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "### Bench comparison",
        "",
        "| bench | base median (ns) | fresh median (ns) | delta |",
        "| --- | ---: | ---: | --- |",
    ]
    for name, base_med, fresh_med, note in rows:
        lines.append(f"| `{name}` | {fmt_ns(base_med)} | {fmt_ns(fresh_med)} | {note} |")
    verdict = (
        f"**FAIL**: {n_regressions} bench(es) regressed > {max_regression:.0%}"
        if n_regressions
        else f"**OK**: no bench regressed more than {max_regression:.0%}"
    )
    lines += ["", verdict, ""]
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main():
    require_baseline = "--require-baseline" in sys.argv[1:]
    args = [a for a in sys.argv[1:] if a != "--require-baseline"]
    if len(args) < 2:
        sys.exit(__doc__)
    base_path, fresh_path = args[0], args[1]
    max_regression = float(args[2]) if len(args) > 2 else 0.25
    base = load(base_path)
    fresh = load(fresh_path)

    if not base:
        msg = (
            f"baseline {base_path} has empty 'results' — the perf gate "
            f"cannot detect regressions against it"
        )
        if require_baseline:
            # CI regenerates the baseline from the merge base, so an empty
            # one means the gate itself is broken — fail, don't warn.
            sys.exit(f"FAIL: {msg} (--require-baseline)")
        # Legacy committed-JSON mode: pass, but LOUDLY, so a quietly-stale
        # baseline can't masquerade as a green perf check.
        print(f"WARNING: {msg}", file=sys.stderr)
        if os.environ.get("GITHUB_ACTIONS") == "true":
            # Workflow-command annotation: shows on the run summary and the
            # PR checks tab, not just buried in the step log.
            print(f"::warning title=bench_compare: empty baseline::{msg}")
        return

    regressions = []
    rows = []  # (name, base_med, fresh_med, note) for the step summary
    print(f"{'bench':<44} {'base med':>12} {'fresh med':>12} {'ratio':>8}")
    for name in sorted(set(base) | set(fresh)):
        base_med = median_of(base.get(name))
        fresh_med = median_of(fresh.get(name))
        if name not in base:
            print(f"{name:<44} {'-':>12} {fmt_ns(fresh_med):>12} {'new':>8}")
            rows.append((name, None, fresh_med, "new"))
            continue
        if name not in fresh:
            print(f"{name:<44} {fmt_ns(base_med):>12} {'-':>12} {'gone':>8}")
            rows.append((name, base_med, None, "gone"))
            continue
        if base_med is None or fresh_med is None:
            # A missing/zero median on either side makes the ratio
            # meaningless — surface it, never fail on it.
            print(f"{name:<44} {fmt_ns(base_med):>12} {fmt_ns(fresh_med):>12} {'n/a':>8}")
            rows.append((name, base_med, fresh_med, "n/a (unusable median)"))
            continue
        ratio = fresh_med / base_med
        regressed = ratio > 1.0 + max_regression
        flag = " <-- REGRESSION" if regressed else ""
        print(f"{name:<44} {base_med:>12.0f} {fresh_med:>12.0f} {ratio:>8.2f}{flag}")
        rows.append((name, base_med, fresh_med, f"{ratio:.2f}x" + (" ⚠️" if regressed else "")))
        if regressed:
            regressions.append((name, ratio))

    write_step_summary(rows, max_regression, len(regressions))
    if regressions:
        worst = ", ".join(f"{n} ({r:.2f}x)" for n, r in regressions)
        sys.exit(f"FAIL: {len(regressions)} bench(es) regressed >" f"{max_regression:.0%}: {worst}")
    print(f"OK: no bench regressed more than {max_regression:.0%}")


if __name__ == "__main__":
    main()

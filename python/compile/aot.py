"""AOT export: lower the JAX/Pallas model to HLO text for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (default `artifacts/`):

* ``decode.hlo.txt``          — verify pass at max_seq_len
* ``decode_len{S}.hlo.txt``   — shorter-context variants for the Fig. 8
                                latency-vs-tokens calibration sweep
* ``train_step.hlo.txt``      — GRPO SGD step
* ``params/<name>.bin``       — initial parameters (f32 little-endian)
* ``meta.json``               — geometry + flattened param inventory

Run as ``python -m compile.aot --out-dir ../artifacts`` from `python/`
(or via ``make artifacts``).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    decode_block,
    grpo_train_step,
    init_params,
    param_names,
    param_shapes,
)

CALIBRATION_LENS = (32, 64, 128)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: ModelConfig, seq_len: int):
    def fn(*args):
        params = list(args[: -2])
        tokens, q_start = args[-2], args[-1]
        return (decode_block(params, tokens, q_start, cfg),)

    specs = [
        jax.ShapeDtypeStruct(param_shapes(cfg)[n], jnp.float32) for n in param_names(cfg)
    ]
    specs.append(jax.ShapeDtypeStruct((cfg.batch, seq_len), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))
    return jax.jit(fn).lower(*specs)


def lower_train(cfg: ModelConfig):
    def fn(*args):
        params = list(args[: -4])
        tokens, mask, adv, lr = args[-4], args[-3], args[-2], args[-1]
        return grpo_train_step(params, tokens, mask, adv, lr, cfg)

    specs = [
        jax.ShapeDtypeStruct(param_shapes(cfg)[n], jnp.float32) for n in param_names(cfg)
    ]
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq_len), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq_len), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch,), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((), jnp.float32))
    return jax.jit(fn).lower(*specs)


def export(cfg: ModelConfig, out_dir: str, seed: int = 0) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params_dir = os.path.join(out_dir, "params")
    os.makedirs(params_dir, exist_ok=True)

    # 1. Initial parameters.
    params = init_params(jax.random.PRNGKey(seed), cfg)
    names = param_names(cfg)
    for name, arr in zip(names, params):
        path = os.path.join(params_dir, name.replace("/", "_") + ".bin")
        with open(path, "wb") as f:
            f.write(bytes(jnp.asarray(arr, jnp.float32).tobytes()))

    # 2. Executables.
    artifacts = {}
    text = to_hlo_text(lower_decode(cfg, cfg.max_seq_len))
    with open(os.path.join(out_dir, "decode.hlo.txt"), "w") as f:
        f.write(text)
    artifacts["decode"] = "decode.hlo.txt"
    for s in CALIBRATION_LENS:
        if s > cfg.max_seq_len:
            continue
        text = to_hlo_text(lower_decode(cfg, s))
        fname = f"decode_len{s}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[f"decode_len{s}"] = fname
    text = to_hlo_text(lower_train(cfg))
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(text)
    artifacts["train_step"] = "train_step.hlo.txt"

    # 3. Metadata for the Rust loader.
    meta = {
        "model": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "max_seq_len": cfg.max_seq_len,
            "batch": cfg.batch,
            "spec_block": cfg.spec_block,
        },
        "params": [
            {"name": n, "shape": list(param_shapes(cfg)[n]),
             "file": "params/" + n.replace("/", "_") + ".bin"}
            for n in names
        ],
        "artifacts": artifacts,
        "calibration_lens": [s for s in CALIBRATION_LENS if s <= cfg.max_seq_len],
        "seed": seed,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab-size", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--spec-block", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = ModelConfig(
        vocab_size=args.vocab_size,
        d_model=args.d_model,
        n_layers=args.n_layers,
        n_heads=args.n_heads,
        max_seq_len=args.max_seq_len,
        batch=args.batch,
        spec_block=args.spec_block,
    )
    meta = export(cfg, args.out_dir, args.seed)
    n_arrays = len(meta["params"])
    print(f"exported {len(meta['artifacts'])} executables + {n_arrays} param arrays "
          f"to {args.out_dir}")


if __name__ == "__main__":
    main()

"""Layer-1 Pallas kernels: causal flash-attention for draft-block verification.

The DAS verify pass is attention where a block of K+1 query positions (the
draft block) attends causally over the full context. On GPU the paper's
substrate (vLLM) does this with custom masked kernels over threadblocks; the
TPU-style rethink here (DESIGN.md §Hardware-Adaptation) expresses the same
schedule with a Pallas BlockSpec grid:

* the grid iterates ``(batch·heads, q_blocks)``;
* each program keeps one ``(block_q, head_dim)`` query tile VMEM-resident
  and streams ``(block_k, head_dim)`` key/value tiles HBM→VMEM;
* softmax is computed online (running max + running sum), so the full
  ``(S, S)`` score matrix never materializes — the FlashAttention trick,
  which on TPU is what keeps the working set inside ~16 MB of VMEM;
* the causal mask is applied per tile from absolute position indices.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for both the pytest
oracle checks and the AOT artifacts. Real-TPU tiling estimates live in
DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, block_k: int,
                      seq_len: int, block_q: int):
    """One (batch·head, q-block) program of causal flash attention.

    q_ref: [block_q, head_dim] — resident query tile.
    k_ref/v_ref: [seq_len, head_dim] — full K/V for this head; the kernel
        walks them in ``block_k`` tiles (the HBM→VMEM stream).
    o_ref: [block_q, head_dim] — output tile.
    """
    q_blk = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    head_dim = q.shape[-1]

    q_pos = q_blk * block_q + jax.lax.iota(jnp.int32, block_q)  # absolute q rows

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_tile = jax.lax.dynamic_slice_in_dim(k_ref[...], kb * block_k, block_k, 0)
        v_tile = jax.lax.dynamic_slice_in_dim(v_ref[...], kb * block_k, block_k, 0)
        k_tile = k_tile.astype(jnp.float32)
        v_tile = v_tile.astype(jnp.float32)
        # (block_q, head_dim) @ (head_dim, block_k) — the MXU-shaped matmul.
        s = q @ k_tile.T
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)
        # Online softmax update.
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + p.sum(axis=-1)
        acc = acc * correction[:, None] + p @ v_tile
        return acc, m_cur, l_cur

    n_kb = seq_len // block_k
    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q: int = 32, block_k: int = 32):
    """Causal multi-head attention via the Pallas kernel (differentiable).

    Forward runs the Pallas kernel; backward is the analytic VJP of the
    reference attention (`jax.custom_vjp` — interpret-mode `pallas_call`
    does not support reverse-mode AD, and a hand-rolled backward kernel
    would be re-deriving what XLA already fuses well on the train path).

    Args:
        q, k, v: ``[batch, heads, seq, head_dim]`` (same shape).
    Returns:
        ``[batch, heads, seq, head_dim]`` attention output, q's dtype.
    """
    return _flash_attention_vjp(q, k, v, block_q, block_k)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attention_vjp(q, k, v, block_q, block_k):
    return _flash_attention_fwd_only(q, k, v, block_q=block_q, block_k=block_k)


def _flash_attention_fwd(q, k, v, block_q, block_k):
    out = _flash_attention_fwd_only(q, k, v, block_q=block_q, block_k=block_k)
    return out, (q, k, v)


def _flash_attention_bwd(block_q, block_k, res, g):
    from . import ref

    q, k, v = res
    _, vjp = jax.vjp(ref.attention_ref, q, k, v)
    return vjp(g)


_flash_attention_vjp.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def _flash_attention_fwd_only(q, k, v, *, block_q: int = 32, block_k: int = 32):
    b, h, s, d = q.shape
    assert k.shape == (b, h, s, d) and v.shape == (b, h, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0, f"seq {s} not divisible by block_q {block_q}"
    assert s % block_k == 0, f"seq {s} not divisible by block_k {block_k}"
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * h, s, d)
    vr = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _attention_kernel,
        scale=scale,
        block_k=block_k,
        seq_len=s,
        block_q=block_q,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            # Query tile: one (block_q, d) tile per program.
            pl.BlockSpec((None, block_q, d), lambda bh, qb: (bh, qb, 0)),
            # Full K/V rows for this head; the kernel streams tiles itself.
            pl.BlockSpec((None, s, d), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qb: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    """Fused RMSNorm tile: one row block per program."""
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


def rmsnorm(x, gain, *, eps: float = 1e-6, block_rows: int = 32):
    """RMS layer norm over the last axis via a Pallas kernel
    (differentiable via the reference VJP, like `flash_attention`)."""
    return _rmsnorm_vjp(x, gain, eps, block_rows)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_vjp(x, gain, eps, block_rows):
    return _rmsnorm_fwd_only(x, gain, eps=eps, block_rows=block_rows)


def _rmsnorm_fwd(x, gain, eps, block_rows):
    return _rmsnorm_fwd_only(x, gain, eps=eps, block_rows=block_rows), (x, gain)


def _rmsnorm_bwd(eps, block_rows, res, g):
    from . import ref

    x, gain = res
    _, vjp = jax.vjp(lambda xx, gg: ref.rmsnorm_ref(xx, gg, eps), x, gain)
    return vjp(g)


_rmsnorm_vjp.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def _rmsnorm_fwd_only(x, gain, *, eps: float = 1e-6, block_rows: int = 32):
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for n in orig_shape[:-1]:
        rows *= n
    xr = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        block_rows = 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(xr, gain)
    return out.reshape(orig_shape)

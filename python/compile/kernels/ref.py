"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `pytest python/tests` asserts the
kernels in `attention.py` match these references across hypothesis-swept
shapes and dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v):
    """Naive causal multi-head attention. q/k/v: [B, H, S, D]."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, gain, eps: float = 1e-6):
    """Naive RMSNorm over the last axis."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * (1.0 / jnp.sqrt(ms + eps)) * gain.astype(jnp.float32)).astype(x.dtype)

"""Layer-2: the JAX policy model (GPT-style) + GRPO train step.

Build-time only — these functions are lowered once by `aot.py` to HLO text
and executed from Rust via PJRT. Python is never on the request path.

Exported computations (all with the flattened parameter list as leading
inputs, in `param_names()` order):

* ``decode_block`` — the speculative VERIFY pass: given padded token ids
  ``[B, S]`` and per-sequence query starts ``[B]``, return logits for the
  ``K+1`` positions beginning at each query start. One call verifies a whole
  draft block per sequence (the paper's "verify in one batched step").
* ``grpo_train_step`` — policy-gradient update: group-normalized advantages
  (GRPO with a single on-policy update, where the importance ratio is 1 and
  the clipped surrogate reduces to REINFORCE), SGD on all parameters.

Attention and RMSNorm run through the Layer-1 Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention, rmsnorm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 64
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    max_seq_len: int = 128
    batch: int = 8
    # Draft block: K draft tokens verified per pass -> K+1 logit rows.
    spec_block: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


def param_names(cfg: ModelConfig) -> List[str]:
    """Canonical flattened parameter order (mirrored by rust meta loader)."""
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1",
            f"l{i}.wq",
            f"l{i}.wk",
            f"l{i}.wv",
            f"l{i}.wo",
            f"l{i}.ln2",
            f"l{i}.w1",
            f"l{i}.w2",
        ]
    names.append("ln_f")
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    shapes = {
        "embed": (cfg.vocab_size, cfg.d_model),
        "pos": (cfg.max_seq_len, cfg.d_model),
        "ln_f": (cfg.d_model,),
    }
    for i in range(cfg.n_layers):
        shapes[f"l{i}.ln1"] = (cfg.d_model,)
        shapes[f"l{i}.wq"] = (cfg.d_model, cfg.d_model)
        shapes[f"l{i}.wk"] = (cfg.d_model, cfg.d_model)
        shapes[f"l{i}.wv"] = (cfg.d_model, cfg.d_model)
        shapes[f"l{i}.wo"] = (cfg.d_model, cfg.d_model)
        shapes[f"l{i}.ln2"] = (cfg.d_model,)
        shapes[f"l{i}.w1"] = (cfg.d_model, cfg.d_ff)
        shapes[f"l{i}.w2"] = (cfg.d_ff, cfg.d_model)
    return shapes


def init_params(key, cfg: ModelConfig) -> List[jnp.ndarray]:
    """He-style init, returned as the flattened list in param_names order."""
    shapes = param_shapes(cfg)
    params = []
    for name in param_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "pos":
            params.append(0.01 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return params


def _unflatten(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return dict(zip(param_names(cfg), flat))


def backbone(params: List[jnp.ndarray], tokens, cfg: ModelConfig):
    """Transformer trunk: tokens [B, S] int32 -> activations [B, S, D]."""
    p = _unflatten(cfg, params)
    b, s = tokens.shape
    h = p["embed"][tokens] + p["pos"][:s][None, :, :]
    for i in range(cfg.n_layers):
        x = rmsnorm(h, p[f"l{i}.ln1"])
        q = (x @ p[f"l{i}.wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = (x @ p[f"l{i}.wk"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = (x @ p[f"l{i}.wv"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        att = flash_attention(q, k, v)
        att = att.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + att @ p[f"l{i}.wo"]
        x = rmsnorm(h, p[f"l{i}.ln2"])
        h = h + jax.nn.gelu(x @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]
    return rmsnorm(h, p["ln_f"])


def forward_logits(params: List[jnp.ndarray], tokens, cfg: ModelConfig):
    """Full next-token logits [B, S, V] (training / testing path)."""
    p = _unflatten(cfg, params)
    h = backbone(params, tokens, cfg)
    return h @ p["embed"].T  # tied unembedding


def decode_block(params: List[jnp.ndarray], tokens, q_start, cfg: ModelConfig):
    """Verify pass: logits for cfg.spec_block positions starting at q_start.

    tokens:  [B, S] int32 — context + draft, right-padded (pad value is
             irrelevant: causal attention means positions after the block
             cannot influence it).
    q_start: [B] int32 — index of the last committed token per sequence;
             row r of the output predicts token (q_start + r + 1).
    returns: [B, spec_block, V] float32 raw logits.
    """
    p = _unflatten(cfg, params)
    h = backbone(params, tokens, cfg)  # [B, S, D]

    def take(h_b, q):
        return jax.lax.dynamic_slice_in_dim(h_b, q, cfg.spec_block, axis=0)

    rows = jax.vmap(take)(h, q_start)  # [B, K+1, D]
    return rows @ p["embed"].T


def _logprobs(logits):
    return jax.nn.log_softmax(logits, axis=-1)


def grpo_loss(params, tokens, mask, advantages, cfg: ModelConfig):
    """REINFORCE-with-baseline surrogate (GRPO, single on-policy update).

    tokens:     [B, S] int32 — prompt + generation, right padded.
    mask:       [B, S] f32 — 1.0 on GENERATED positions (prediction targets),
                0 on prompt/pad.
    advantages: [B] f32 — group-normalized rewards.
    """
    logits = forward_logits(params, tokens, cfg)  # predicts token t+1 at row t
    logp = _logprobs(logits[:, :-1, :])
    targets = tokens[:, 1:]
    tgt_logp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    per_seq = (tgt_logp * m).sum(axis=-1) / jnp.maximum(m.sum(axis=-1), 1.0)
    loss = -(advantages * per_seq).mean()
    return loss


def grpo_train_step(params, tokens, mask, advantages, lr, cfg: ModelConfig):
    """One SGD step on the GRPO loss. Returns (new_params..., loss)."""
    loss, grads = jax.value_and_grad(grpo_loss)(params, tokens, mask, advantages, cfg)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss,)

"""L2 correctness: model invariants + train-step behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    decode_block,
    forward_logits,
    grpo_loss,
    grpo_train_step,
    init_params,
    param_names,
    param_shapes,
)

CFG = ModelConfig(vocab_size=32, d_model=32, n_layers=2, n_heads=4,
                  max_seq_len=32, batch=2, spec_block=4)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def test_param_inventory_consistent():
    names = param_names(CFG)
    shapes = param_shapes(CFG)
    assert len(names) == len(set(names))
    assert set(names) == set(shapes)
    # 2 + 8 per layer + 1
    assert len(names) == 2 + 8 * CFG.n_layers + 1


def test_forward_shapes(params):
    tokens = jnp.zeros((CFG.batch, CFG.max_seq_len), jnp.int32)
    logits = forward_logits(params, tokens, CFG)
    assert logits.shape == (CFG.batch, CFG.max_seq_len, CFG.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_model_is_causal(params):
    """Changing a future token must not change past logits."""
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (CFG.batch, CFG.max_seq_len), 0, CFG.vocab_size)
    base = forward_logits(params, tokens, CFG)
    pert = tokens.at[:, 20].set((tokens[:, 20] + 1) % CFG.vocab_size)
    out = forward_logits(params, pert, CFG)
    np.testing.assert_allclose(base[:, :20], out[:, :20], rtol=1e-4, atol=1e-5)
    assert not np.allclose(base[:, 20:], out[:, 20:])


def test_decode_block_matches_full_forward(params):
    """The AOT verify pass must equal the corresponding full-logit rows."""
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (CFG.batch, CFG.max_seq_len), 0, CFG.vocab_size)
    q_start = jnp.array([5, 9], jnp.int32)
    block = decode_block(params, tokens, q_start, CFG)
    full = forward_logits(params, tokens, CFG)
    for b in range(CFG.batch):
        np.testing.assert_allclose(
            np.asarray(block[b]),
            np.asarray(full[b, q_start[b]:q_start[b] + CFG.spec_block]),
            rtol=1e-4, atol=1e-5,
        )


def test_decode_block_padding_independent(params):
    """Tokens AFTER the query block must not affect block logits (causality
    is what lets the runtime right-pad with garbage)."""
    key = jax.random.PRNGKey(5)
    tokens = jax.random.randint(key, (CFG.batch, CFG.max_seq_len), 0, CFG.vocab_size)
    q_start = jnp.array([6, 6], jnp.int32)
    a = decode_block(params, tokens, q_start, CFG)
    # Scramble everything after position q_start + spec_block.
    tail = 6 + CFG.spec_block
    scrambled = tokens.at[:, tail:].set(0)
    b = decode_block(params, scrambled, q_start, CFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_train_step_reduces_loss(params):
    """Positive-advantage sequences must become more likely (lower loss)."""
    key = jax.random.PRNGKey(6)
    tokens = jax.random.randint(key, (CFG.batch, CFG.max_seq_len), 0, CFG.vocab_size)
    mask = jnp.ones((CFG.batch, CFG.max_seq_len), jnp.float32).at[:, :4].set(0.0)
    adv = jnp.ones((CFG.batch,), jnp.float32)
    lr = jnp.float32(0.5)
    p = params
    losses = []
    for _ in range(5):
        out = grpo_train_step(p, tokens, mask, adv, lr, CFG)
        p, loss = list(out[:-1]), out[-1]
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_train_step_preserves_shapes(params):
    tokens = jnp.zeros((CFG.batch, CFG.max_seq_len), jnp.int32)
    mask = jnp.ones((CFG.batch, CFG.max_seq_len), jnp.float32)
    adv = jnp.zeros((CFG.batch,), jnp.float32)
    out = grpo_train_step(params, tokens, mask, adv, jnp.float32(0.1), CFG)
    new_params, loss = out[:-1], out[-1]
    assert len(new_params) == len(params)
    for a, b in zip(new_params, params):
        assert a.shape == b.shape
    # Zero advantage => zero gradient => params unchanged.
    for a, b in zip(new_params, params):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    assert float(loss) == pytest.approx(0.0, abs=1e-6)


def test_grpo_loss_sign():
    """Higher-probability sequences with positive advantage -> lower loss."""
    p = init_params(jax.random.PRNGKey(7), CFG)
    tokens = jnp.zeros((CFG.batch, CFG.max_seq_len), jnp.int32)
    mask = jnp.ones((CFG.batch, CFG.max_seq_len), jnp.float32)
    pos = grpo_loss(p, tokens, mask, jnp.ones((CFG.batch,)), CFG)
    neg = grpo_loss(p, tokens, mask, -jnp.ones((CFG.batch,)), CFG)
    assert float(pos) == pytest.approx(-float(neg), rel=1e-5)

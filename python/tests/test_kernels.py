"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis-swept)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, rmsnorm
from compile.kernels.ref import attention_ref, rmsnorm_ref

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@settings(max_examples=24, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_pow=st.integers(2, 6),  # seq in {4..64}
    d=st.sampled_from([4, 8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(b, h, s_pow, d, dtype, seed):
    s = 2 ** s_pow
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q, k, v = (rand(kk_, (b, h, s, d), dtype) for kk_ in (kq, kk, kv))
    got = flash_attention(q, k, v, block_q=min(16, s), block_k=min(16, s))
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
    )


@settings(max_examples=24, deadline=None)
@given(
    rows=st.integers(1, 64),
    d=st.sampled_from([8, 16, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(rows, d, dtype, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (rows, d), dtype)
    g = rand(k2, (d,), jnp.float32)
    got = rmsnorm(x, g)
    want = rmsnorm_ref(x, g)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
    )


def test_attention_is_causal():
    """Perturbing token t must not change outputs at positions < t."""
    key = jax.random.PRNGKey(0)
    b, h, s, d = 1, 2, 16, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    base = flash_attention(q, k, v)
    k2 = k.at[:, :, 10, :].add(100.0)
    v2 = v.at[:, :, 10, :].add(-50.0)
    pert = flash_attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, :10], pert[:, :, :10], rtol=1e-6)
    assert not np.allclose(base[:, :, 10:], pert[:, :, 10:])


def test_attention_uniform_values_passthrough():
    """With identical V rows, attention output equals that row."""
    b, h, s, d = 1, 1, 8, 4
    q = jnp.ones((b, h, s, d))
    k = jnp.ones((b, h, s, d))
    v = jnp.broadcast_to(jnp.arange(d, dtype=jnp.float32), (b, h, s, d))
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(out[0, 0, 3], jnp.arange(d, dtype=jnp.float32), rtol=1e-6)


def test_rmsnorm_scale_invariance():
    """RMSNorm(c*x) == RMSNorm(x) for c > 0 (up to eps)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    g = jnp.ones((16,))
    a = rmsnorm(x, g)
    b = rmsnorm(3.0 * x, g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_rmsnorm_handles_odd_row_counts():
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 8))  # 7 % 32 != 0
    g = jnp.ones((8,))
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, g)), np.asarray(rmsnorm_ref(x, g)), rtol=2e-5, atol=2e-5
    )

"""AOT export round-trip: HLO text parses, meta is complete, params dump."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export, lower_decode, lower_train, to_hlo_text
from compile.model import ModelConfig, param_names

CFG = ModelConfig(vocab_size=16, d_model=16, n_layers=1, n_heads=2,
                  max_seq_len=16, batch=2, spec_block=4)


def test_hlo_text_is_parseable_hlo(tmp_path):
    text = to_hlo_text(lower_decode(CFG, CFG.max_seq_len))
    assert "HloModule" in text
    assert "ENTRY" in text
    # The entry computation takes params + tokens + q_start.
    n_inputs = len(param_names(CFG)) + 2
    assert text.count("parameter(") >= n_inputs


def test_train_hlo_has_all_outputs():
    text = to_hlo_text(lower_train(CFG))
    assert "HloModule" in text
    # Output tuple: n_params new params + loss.
    assert "tuple(" in text or "ROOT" in text


def test_export_writes_everything(tmp_path):
    meta = export(CFG, str(tmp_path), seed=3)
    with open(tmp_path / "meta.json") as f:
        loaded = json.load(f)
    assert loaded == meta
    assert (tmp_path / "decode.hlo.txt").exists()
    assert (tmp_path / "train_step.hlo.txt").exists()
    for p in meta["params"]:
        f = tmp_path / p["file"]
        assert f.exists()
        expect = 4 * int(np.prod(p["shape"]))
        assert os.path.getsize(f) == expect, p["name"]
    # Calibration variants only up to max_seq_len.
    for s in meta["calibration_lens"]:
        assert s <= CFG.max_seq_len
        assert (tmp_path / f"decode_len{s}.hlo.txt").exists()


def test_exported_params_reproducible(tmp_path):
    m1 = export(CFG, str(tmp_path / "a"), seed=5)
    m2 = export(CFG, str(tmp_path / "b"), seed=5)
    for p1, p2 in zip(m1["params"], m2["params"]):
        b1 = (tmp_path / "a" / p1["file"]).read_bytes()
        b2 = (tmp_path / "b" / p2["file"]).read_bytes()
        assert b1 == b2


def test_decode_numerics_via_roundtrip(tmp_path):
    """Execute the lowered decode through jax and compare against the
    un-lowered function — guards against lowering bugs before the Rust side
    ever sees the artifact."""
    from compile.model import decode_block, init_params
    params = init_params(jax.random.PRNGKey(0), CFG)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (CFG.batch, CFG.max_seq_len),
                                0, CFG.vocab_size)
    q_start = jnp.array([3, 7], jnp.int32)
    lowered = lower_decode(CFG, CFG.max_seq_len)
    compiled = lowered.compile()
    got = compiled(*params, tokens, q_start)[0]
    want = decode_block(params, tokens, q_start, CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
